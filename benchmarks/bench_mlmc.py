"""Lemma 3.1 empirical check: bias / variance / cost of the MLMC estimator
built on robust aggregation (Lemma 3.3: the aggregated mini-batch estimator
satisfies the MSE ∝ 1/N premise)."""
from __future__ import annotations

import math

import numpy as np

from repro.core.mlmc import MLMCConfig, round_cost, sample_level


def run(T: int = 1024, m: int = 16, n_byz: int = 4, trials: int = 30_000, seed: int = 0):
    rng = np.random.default_rng(seed)
    true = 1.0
    sigma = 1.0
    cfg = MLMCConfig(T=T, m=m, V=3 * sigma, option=1, kappa=0.5)

    def agg_level(n):
        """CWMed of m mini-batch means, n_byz send +3σ/√n (hiding in noise):
        the estimator of Lemma 3.3 — MSE ~ c²/n with a bias term the MLMC
        construction must kill."""
        g = true + rng.normal(size=m) * sigma / math.sqrt(n)
        g[:n_byz] = true + 3 * sigma / math.sqrt(n)
        return float(np.median(g))

    outs, costs = [], []
    for _ in range(trials):
        j = sample_level(rng, cfg.j_max)  # truncated at j_max + 1
        g0 = agg_level(1)
        if j <= cfg.j_max:
            g = g0 + (2 ** j) * (agg_level(2 ** j) - agg_level(2 ** (j - 1)))
        else:  # beyond cap: correction dropped
            g = g0
        costs.append(round_cost(j, cfg.j_max))
        outs.append(g)
    outs = np.asarray(outs)
    bias_mlmc = abs(outs.mean() - true)
    bias_single = abs(np.mean([agg_level(1) for _ in range(trials // 4)]) - true)
    return {
        "bias_mlmc": bias_mlmc,
        "bias_single_level": bias_single,
        "bias_bound_sqrt2c2_T": math.sqrt(2 / T) * 3 * sigma,
        "var_mlmc": float(outs.var()),
        "var_bound_14c2logT": 14 * (3 * sigma) ** 2 * math.log(T),
        "mean_cost": float(np.mean(costs)),
        "cost_bound_OlogT": 1 + 1.5 * math.log2(T),
    }


def main(fast: bool = False):
    r = run(trials=5000 if fast else 30_000)
    return [f"mlmc_lemma31/{k},,{v:.4f}" for k, v in r.items()]


if __name__ == "__main__":
    print("\n".join(main()))
