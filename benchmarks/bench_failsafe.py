"""Fail-safe filter ablation (Section 4 / Eq. 6 — no direct paper figure, but
the mechanism behind Theorem 4.1's |τ_d| term).

Dynamic rounds: worker identities flip *within* the round (data-poisoning
model), corrupting the high MLMC levels with probability growing in 2^J.
Without the fail-safe, the 2^J-scaled correction injects unbounded bias;
with it, corrupted corrections are rejected and the estimator falls back to
ĝ⁰. We sweep the attack magnitude and report final optimality gaps and the
filter's trip statistics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._clf import seed_stat
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig, run_dynabro
from repro.optim.optimizers import sgd

A = jnp.array([[2.0, 1.0], [1.0, 2.0]])
SIGMA = 0.5
P0 = {"x": jnp.array([3.0, -2.0])}


def grad_fn(params, unit_key):
    return {"x": A @ params["x"] + SIGMA * jax.random.normal(unit_key, (2,))}


def sampler(m, seed=0):
    def sample(t, n):
        keys = jax.random.split(jax.random.fold_in(jax.random.PRNGKey(seed), t), m * n)
        return keys.reshape(m, n, *keys.shape[1:])
    return sample


class WithinRound:
    """Identities flip after the first in-round computation on every 10th
    round: |τ_d| = T/10 (sublinear-ish, the Cor. 4.2 regime) and the
    corruption hits ĝ^J / ĝ^{J-1} asymmetrically (adversarial case of
    Lemma D.4) — the 2^J-scaled level difference carries an O(v) bias that
    only the fail-safe can reject once v exceeds the Eq. 6 threshold."""

    def __init__(self, m, every: int = 10):
        self.m = m
        self.every = every

    def mask(self, t):
        return np.zeros(self.m, bool)

    def within_round(self, t, k):
        mk = np.zeros(self.m, bool)
        if k >= 1 and t % self.every == 0:
            mk[: self.m // 2] = True
        return mk


def run(T: int = 400, seeds=(0, 1, 2)):
    m = 8
    rows = []
    for v in (200.0, 2000.0):
        for use_fs in (True, False):
            finals, trips, dyn = [], [], []
            for s in seeds:
                cfg = DynaBROConfig(
                    mlmc=MLMCConfig(T=T, m=m, V=4 * SIGMA + 1, option=1,
                                    kappa=1.0, use_failsafe=use_fs),
                    aggregator="cwmed", attack="shift", attack_kwargs={"v": v})
                p, logs, _ = run_dynabro(grad_fn, P0, sgd(1e-2), cfg,
                                         WithinRound(m), sampler(m, s), T, seed=s)
                f = float(0.5 * p["x"] @ A @ p["x"])
                finals.append(min(f, 1e9) if np.isfinite(f) else 1e9)
                trips.append(sum(1 for l in logs if l.level >= 1 and not l.failsafe_ok))
                dyn.append(sum(1 for t_, l in enumerate(logs)
                               if l.level >= 1 and t_ % 10 == 0))
            rows.append((f"v{v}_failsafe={'on' if use_fs else 'off'}", finals,
                         float(np.mean(trips)), float(np.mean(dyn))))
    return rows


def main(fast: bool = False):
    rows = run(T=150 if fast else 400, seeds=(0,) if fast else (0, 1, 2))
    return [f"failsafe_ablation/{n},,{seed_stat('final_gap', finals)}"
            f";trips={t:.0f}/{d:.0f}_dyn_rounds"
            for n, finals, t, d in rows]


if __name__ == "__main__":
    print("\n".join(main()))
