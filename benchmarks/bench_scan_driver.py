"""Compiled vs Python-loop DynaBRO driver wall-clock (DESIGN.md §5, §7).

Times full ``run_dynabro`` (legacy per-round dispatch) against
``run_dynabro_scan`` (whole loop in one chunked ``lax.scan``) on the
quadratic testbed at T ∈ {64, 256}, steady state (prebuilt step / scan fn,
one warmup run so jit caches are hot; the schedules repeat per seed so the
warmup covers every level the timed run dispatches). Asserts the two drivers
agree bitwise on the final iterate before timing — a benchmark that compares
non-equivalent code is meaningless.

Two more row families feed the CI perf gates (benchmarks/check_regression.py):

* ``sharded_T256`` — the shard_map'd driver on a **1-device** worker mesh
  (bitwise-asserted against ``scan_T256``): its overhead over the unsharded
  scan is the price of the sharding substrate, which must stay marginal.
* ``sweep_loop_C8`` / ``sweep_vmap_C8`` — an 8-cell switcher sweep through
  per-cell compiled calls vs one vmapped lane-batched call
  (``run_dynabro_scan_sweep``); the vmapped row must hold a ≥2x speedup.
* ``sweep_attack_loop_A4xS4`` / ``sweep_vmap_attacks`` — a 4-attack ×
  4-switcher grid through one vmapped call per attack group (the old
  grouping) vs all 16 lanes in a single call with the per-lane attack
  dispatch; the lane-batched row must hold a ≥2x speedup.
* ``sweep_agg_loop_G4`` / ``sweep_vmap_aggs`` — the full 4-attack ×
  4-switcher × 4-aggregator grid through one vmapped call per aggregator
  group (the PR-4 grouping) vs all 64 lanes in a SINGLE call with per-lane
  attack AND aggregator dispatch (DESIGN.md §7); the one-dispatch row must
  hold a ≥1.5x speedup. The aggregator axis is CWTM at four deltas — the
  traced-hyperparameter lanes this PR makes expressible (under the old
  name-keyed grouping, delta was global and the four cells NEEDED four
  dispatches), and a shape whose ``agg_switch`` collapses to one branch so
  the gated number isolates dispatch amortization.
* ``sweep_agg_loop`` / ``sweep_vmap_mixed_aggs`` — a 4-rule × 4-switcher
  grid mixing *distinct* aggregation rules (CWMed / CWTM / Krum /
  nnm+cwmed) through the per-cell compiled driver vs one grouped sweep
  call: branch-homogeneous lane grouping (DESIGN.md §7) splits the grid
  into one single-rule sub-dispatch per distinct rule, so no lane pays the
  vmapped ``lax.switch``'s execute-all-branches select that used to leave
  mixed grids near break-even (correctness-locked but not perf-gated
  before the grouping landed); the grouped row must hold a ≥1.5x speedup.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import (
    DynaBROConfig, _lane_attack_plan, make_dynabro_scan_fn, make_dynabro_step,
    run_dynabro, run_dynabro_scan, run_dynabro_scan_sweep,
)
from repro.core.scenarios import make_quadratic_task
from repro.core.switching import get_switcher
from repro.launch.mesh import make_worker_mesh
from repro.lint.runtime import recompile_guard
from repro.optim.optimizers import sgd

SWEEP_KS = (5, 8, 10, 15, 20, 25, 40, 50)  # C=8 periodic switcher cells
ATTACK_SPECS = ("sign_flip", ("ipm", {"eps": 0.3}), "alie", "none")
ATTACK_KS = (5, 10, 20, 50)  # the switcher column of the attack grid
# the aggregator axis of the full grid: CWTM at four deltas — the traced
# hyperparameter lanes (deltas explicit so the per-group baseline cfg and
# the contender's lane thetas agree exactly; see module docstring)
AGG_SPECS = (("cwtm", {"delta": 0.1}), ("cwtm", {"delta": 0.2}),
             ("cwtm", {"delta": 0.3}), ("cwtm", {"delta": 0.45}))
# the mixed-rule grid: four DISTINCT rules (deltas explicit so the per-cell
# baseline cfgs and the grouped sweep's lane thetas agree exactly — a bare
# krum lane would default delta=0.25 while the baseline cfg carries 0.45)
AGG_MIX_SPECS = (("cwmed", {}), ("cwtm", {"delta": 0.3}),
                 ("krum", {"delta": 0.45}), ("nnm+cwmed", {"delta": 0.45}))


# backend compiles observed inside any _time timed loop — after the warmup
# call, every timed iteration must ride the jit cache; the total feeds the
# scan_driver/recompiles_steady row and its 0-compile CI gate (DESIGN.md §11)
_STEADY_RECOMPILES = 0


def _time(fn, iters: int):
    global _STEADY_RECOMPILES
    fn()  # warmup: compiles + populates per-level jit caches
    with recompile_guard("bench_scan_driver timed loop", action="count") as g:
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn()
        jax.block_until_ready(jax.tree.leaves(out[0]))
        us = (time.perf_counter() - t0) / iters * 1e6
    _STEADY_RECOMPILES += g.count
    return us


def _setup(T: int, m: int):
    task = make_quadratic_task()
    cfg = DynaBROConfig(mlmc=MLMCConfig(T=T, m=m, V=3.0, kappa=1.0),
                        aggregator="cwmed", delta=0.45, attack="sign_flip")
    return task, cfg, task.make_sampler(m), sgd(2e-2)


def run(T: int, m: int = 9, iters: int = 3, seed: int = 0,
        sharded: bool = False):
    """(us_legacy, us_scan[, us_sharded]): Python loop vs compiled scan —
    plus, when ``sharded``, the shard_map'd scan on a 1-device worker mesh —
    all bitwise-checked before timing."""
    task, cfg, sampler, opt = _setup(T, m)
    step = make_dynabro_step(task.grad_fn, cfg, opt)
    scan_fn = make_dynabro_scan_fn(task.grad_fn, cfg, opt)

    def legacy():
        sw = get_switcher("periodic", m, n_byz=4, K=20, seed=seed)
        return run_dynabro(task.grad_fn, task.params0, opt, cfg, sw, sampler,
                           T, seed=seed, step=step)

    def scan():
        sw = get_switcher("periodic", m, n_byz=4, K=20, seed=seed)
        return run_dynabro_scan(task.grad_fn, task.params0, opt, cfg, sw,
                                sampler, T, seed=seed, scan_fn=scan_fn)

    p_legacy = legacy()[0]
    p_scan = scan()[0]
    np.testing.assert_array_equal(np.asarray(p_legacy["x"]),
                                  np.asarray(p_scan["x"]))
    us_legacy = _time(legacy, iters)
    us_scan = _time(scan, iters)
    if not sharded:
        return us_legacy, us_scan
    mesh = make_worker_mesh(1)
    shard_fn = make_dynabro_scan_fn(task.grad_fn, cfg, opt, mesh=mesh)

    def sharded_run():
        sw = get_switcher("periodic", m, n_byz=4, K=20, seed=seed)
        return run_dynabro_scan(task.grad_fn, task.params0, opt, cfg, sw,
                                sampler, T, seed=seed, scan_fn=shard_fn,
                                mesh=mesh)

    np.testing.assert_array_equal(np.asarray(p_scan["x"]),
                                  np.asarray(sharded_run()[0]["x"]))
    us_sharded = _time(sharded_run, iters)
    return us_legacy, us_scan, us_sharded


def run_sweep(T: int = 64, m: int = 9, iters: int = 3, seed: int = 0):
    """(us_loop, us_vmap) for the C-cell switcher sweep, equality-checked."""
    task, cfg, sampler, opt = _setup(T, m)
    scan_fn = make_dynabro_scan_fn(task.grad_fn, cfg, opt)

    def make_sws():
        return [get_switcher("periodic", m, n_byz=4, K=K, seed=seed)
                for K in SWEEP_KS]

    def loop():
        return [run_dynabro_scan(task.grad_fn, task.params0, opt, cfg, sw,
                                 sampler, T, seed=seed, scan_fn=scan_fn)
                for sw in make_sws()]

    def vmapped():
        return run_dynabro_scan_sweep(task.grad_fn, task.params0, opt, cfg,
                                      make_sws(), sampler, T, seed=seed,
                                      scan_fn=scan_fn)

    per_cell = loop()
    lanes = vmapped()
    for (p_ref, _, _), (p_lane, _) in zip(per_cell, lanes):
        np.testing.assert_allclose(np.asarray(p_ref["x"]),
                                   np.asarray(p_lane["x"]),
                                   rtol=1e-6, atol=1e-7)

    def t_loop():
        outs = loop()
        return (outs[-1][0],)  # _time blocks on the last cell's params

    def t_vmap():
        outs = vmapped()
        return (outs[-1][0],)

    return _time(t_loop, iters), _time(t_vmap, iters)


def run_attack_sweep(T: int = 64, m: int = 9, iters: int = 3, seed: int = 0):
    """(us_group_loop, us_lanes) for the 4-attack × 4-switcher grid.

    The baseline is the pre-lane-batching grouping: one vmapped sweep per
    (attack, kwargs) group — 4 steady-state dispatches (scan_fns prebuilt per
    group, wrappers held in the sweep's MRU cache). The contender runs all
    16 cells as lanes of ONE call via the per-lane attack dispatch. Lanes
    are equality-checked against the group loop at the sweep tolerance
    before timing."""
    task, cfg, sampler, opt = _setup(T, m)
    specs = [(a, {}) if isinstance(a, str) else a for a in ATTACK_SPECS]
    group_cfgs = [dataclasses.replace(cfg, attack=n, attack_kwargs=kw or None)
                  for n, kw in specs]
    group_fns = [make_dynabro_scan_fn(task.grad_fn, c, opt)
                 for c in group_cfgs]
    lane_attacks = [a for a in ATTACK_SPECS for _ in ATTACK_KS]
    # derive the lax.switch branch order from the sweep's own plan, so the
    # prebuilt lane_fn always passes its lane_attacks consistency check
    lane_names, _, _ = _lane_attack_plan(lane_attacks)
    lane_fn = make_dynabro_scan_fn(task.grad_fn, cfg, opt,
                                   lane_attacks=lane_names)

    def make_sws():
        return [get_switcher("periodic", m, n_byz=4, K=K, seed=seed)
                for K in ATTACK_KS]

    def group_loop():
        outs = []
        for c, fn in zip(group_cfgs, group_fns):
            outs.extend(run_dynabro_scan_sweep(
                task.grad_fn, task.params0, opt, c, make_sws(), sampler, T,
                seed=seed, scan_fn=fn))
        return outs

    def lanes():
        return run_dynabro_scan_sweep(
            task.grad_fn, task.params0, opt, cfg,
            [sw for _ in specs for sw in make_sws()], sampler, T, seed=seed,
            scan_fn=lane_fn, attacks=lane_attacks)

    per_group = group_loop()
    per_lane = lanes()
    for (p_ref, logs_ref), (p_lane, logs_lane) in zip(per_group, per_lane):
        assert logs_ref == logs_lane
        np.testing.assert_allclose(np.asarray(p_ref["x"]),
                                   np.asarray(p_lane["x"]),
                                   rtol=1e-6, atol=1e-7)

    def t_loop():
        outs = group_loop()
        return (outs[-1][0],)

    def t_lanes():
        outs = lanes()
        return (outs[-1][0],)

    return _time(t_loop, iters), _time(t_lanes, iters)


def run_agg_sweep(T: int = 64, m: int = 9, iters: int = 3, seed: int = 0):
    """(us_group_loop, us_one_dispatch) for the 4×4×4 attack × switcher ×
    aggregator grid.

    The baseline is the pre-aggregator-lane grouping: one attack-lane sweep
    per aggregator group — 4 steady-state dispatches (scan_fns prebuilt per
    group). The contender runs all 64 cells as lanes of ONE call via the
    per-lane attack AND aggregator dispatch. Lanes are equality-checked
    (exact round logs, sweep-tolerance finals) against the group loop
    before timing."""
    task, cfg, sampler, opt = _setup(T, m)
    lane_attacks = [a for a in ATTACK_SPECS for _ in ATTACK_KS]  # 16/group
    lane_names, _, _ = _lane_attack_plan(lane_attacks)
    group_cfgs = [dataclasses.replace(cfg, aggregator=n,
                                      delta=kw.get("delta", cfg.delta),
                                      aggregator_kwargs=dict(kw) or None)
                  for n, kw in AGG_SPECS]
    group_fns = [make_dynabro_scan_fn(task.grad_fn, c, opt,
                                      lane_attacks=lane_names)
                 for c in group_cfgs]
    agg_names = tuple(dict.fromkeys(n for n, _ in AGG_SPECS))
    full_fn = make_dynabro_scan_fn(task.grad_fn, cfg, opt,
                                   lane_attacks=lane_names,
                                   lane_aggregators=agg_names)
    agg_lanes = [(n, dict(kw)) for n, kw in AGG_SPECS for _ in lane_attacks]
    atk_lanes = lane_attacks * len(AGG_SPECS)

    def make_sws():
        return [get_switcher("periodic", m, n_byz=4, K=K, seed=seed)
                for K in ATTACK_KS]

    def group_sws():
        return [sw for _ in ATTACK_SPECS for sw in make_sws()]

    def group_loop():
        outs = []
        for c, fn in zip(group_cfgs, group_fns):
            outs.extend(run_dynabro_scan_sweep(
                task.grad_fn, task.params0, opt, c, group_sws(), sampler, T,
                seed=seed, scan_fn=fn, attacks=lane_attacks))
        return outs

    def lanes():
        return run_dynabro_scan_sweep(
            task.grad_fn, task.params0, opt, cfg,
            [sw for _ in AGG_SPECS for sw in group_sws()], sampler, T,
            seed=seed, scan_fn=full_fn, attacks=atk_lanes,
            aggregators=agg_lanes)

    per_group = group_loop()
    per_lane = lanes()
    assert len(per_group) == len(per_lane) == 64
    for (p_ref, logs_ref), (p_lane, logs_lane) in zip(per_group, per_lane):
        assert logs_ref == logs_lane
        np.testing.assert_allclose(np.asarray(p_ref["x"]),
                                   np.asarray(p_lane["x"]),
                                   rtol=1e-6, atol=1e-7)

    def t_loop():
        outs = group_loop()
        return (outs[-1][0],)

    def t_lanes():
        outs = lanes()
        return (outs[-1][0],)

    return _time(t_loop, iters), _time(t_lanes, iters)


def run_seed_sweep(T: int = 64, m: int = 9, iters: int = 3, seed: int = 0,
                   R: int = 4):
    """(us_loop, us_replanes) for the replicate-statistics axis
    (DESIGN.md §12): C=4 switcher cells × R seed replicates as replicate
    lanes of ONE vmapped dispatch vs the looped per-seed runs they replace —
    one single-lane driver call per (cell, seed), the shape the benchmarks
    ran before the replicate axis existed. The loop pays C·R batch-schedule
    precomputes and dispatches where the lane axis pays R (replicate streams
    shared across cells); that amortization is the cost-of-error-bars win
    the gate keeps. Replicate lane (c, r) is bitwise the looped run at
    (cell c, seed r) — asserted before timing."""
    from repro.api.session import Session, _task_sampler_factory
    from repro.api.specs import SweepSpec
    task, cfg, sampler, opt = _setup(T, m)
    scan_fn = make_dynabro_scan_fn(task.grad_fn, cfg, opt)
    sess = Session(cfg, grad_fn=task.grad_fn, params0=task.params0, opt=opt,
                   m=m, sample_batches=sampler, seed=seed,
                   sampler_factory=_task_sampler_factory(task, m))
    sws = tuple(("periodic", dict(n_byz=4, K=K)) for K in ATTACK_KS)
    rep_seeds = tuple(seed + r for r in range(R))
    spec_rep = SweepSpec(switchers=sws, seeds=rep_seeds, scan_fn=scan_fn)
    spec_cells = [SweepSpec(switchers=(sw,), seeds=(s,), scan_fn=scan_fn)
                  for s in rep_seeds for sw in sws]

    def loop():
        return [sess.sweep(sp, T) for sp in spec_cells]

    def replanes():
        return sess.sweep(spec_rep, T)

    rep, per = replanes(), loop()
    for i in range(len(spec_cells)):
        r, c = divmod(i, len(sws))
        assert rep[c][r][1] == per[i][0][1]
        np.testing.assert_array_equal(np.asarray(rep[c][r][0]["x"]),
                                      np.asarray(per[i][0][0]["x"]))

    def t_loop():
        outs = loop()
        return (outs[-1][-1][0],)

    def t_rep():
        outs = replanes()
        return (outs[-1][-1][0],)

    return _time(t_loop, iters), _time(t_rep, iters)


def run_big_grid(T: int = 8, m: int = 9, iters: int = 1, seed: int = 0,
                 lane_chunk: int = 64):
    """us + lane count for the 1000+-lane streamed grid (DESIGN.md §12):
    4 attacks × 4 switchers × (4 rules × 4 hyperparameters) × 4 seed
    replicates = 1024 lanes, streamed through ``lane_chunk``-cell dispatches
    with incremental host-side accumulation. Rule-major cell order keeps
    each chunk branch-homogeneous, and the prebuilt ``{rule: scan_fn}``
    mapping keeps every chunk on the identity-cached vmapped wrapper."""
    from repro.api.session import Session, _task_sampler_factory
    from repro.api.specs import SweepSpec
    task, cfg, sampler, opt = _setup(T, m)
    rules = [("cwmed", lambda th: {"delta": th}),
             ("cwtm", lambda th: {"delta": th}),
             ("krum", lambda th: {"delta": th}),
             ("mfm", lambda th: {"tau": th})]
    thetas = (0.1, 0.2, 0.3, 0.45)
    cells = [(atk, K, rule, mk(th))
             for rule, mk in rules for th in thetas
             for atk in ATTACK_SPECS for K in ATTACK_KS]
    lane_names, _, _ = _lane_attack_plan(list(ATTACK_SPECS))
    group_fns = {
        rule: make_dynabro_scan_fn(task.grad_fn, cfg, opt,
                                   lane_attacks=lane_names,
                                   lane_aggregators=(rule,))
        for rule, _ in rules}
    sess = Session(cfg, grad_fn=task.grad_fn, params0=task.params0, opt=opt,
                   m=m, sample_batches=sampler, seed=seed,
                   sampler_factory=_task_sampler_factory(task, m))
    spec = SweepSpec(
        switchers=tuple(("periodic", dict(n_byz=4, K=K))
                        for _, K, _, _ in cells),
        attacks=tuple((a, {}) if isinstance(a, str) else a
                      for a, _, _, _ in cells),
        aggregators=tuple((r, kw) for _, _, r, kw in cells),
        seeds=tuple(seed + r for r in range(4)),
        scan_fn=group_fns)

    def grid():
        return sess.sweep(spec, T, lane_chunk=lane_chunk)

    outs = grid()
    n_lanes = sum(len(cell) for cell in outs)
    assert n_lanes == len(cells) * 4 >= 1000, n_lanes
    us = _time(lambda: (grid()[-1][-1][0],), iters)
    return us, n_lanes, -(-len(cells) // lane_chunk)


def run_mixed_agg_sweep(T: int = 64, m: int = 9, iters: int = 3,
                        seed: int = 0):
    """(us_cell_loop, us_grouped) for the 4-rule × 4-switcher MIXED-rule
    grid — the shape the old aggregator grouping could not lane-batch.

    The baseline runs each of the 16 cells through the per-cell compiled
    driver (4 prebuilt plain scan_fns, one per rule — steady state). The
    contender runs the whole grid through ONE ``run_dynabro_scan_sweep``
    call with a prebuilt ``{rule: scan_fn}`` mapping: branch-homogeneous
    lane grouping (DESIGN.md §7) splits it into 4 single-rule vmapped
    dispatches, so no lane pays the execute-all-branches ``lax.switch``.
    Exact round logs + sweep-tolerance finals asserted before timing."""
    task, cfg, sampler, opt = _setup(T, m)
    cells = [(n, dict(kw), K) for n, kw in AGG_MIX_SPECS for K in ATTACK_KS]
    cell_cfgs = {n: dataclasses.replace(cfg, aggregator=n,
                                        delta=kw.get("delta", cfg.delta),
                                        aggregator_kwargs=dict(kw) or None)
                 for n, kw in AGG_MIX_SPECS}
    cell_fns = {n: make_dynabro_scan_fn(task.grad_fn, c, opt)
                for n, c in cell_cfgs.items()}
    group_fns = {n: make_dynabro_scan_fn(task.grad_fn, cfg, opt,
                                         lane_aggregators=(n,))
                 for n, _ in AGG_MIX_SPECS}

    def sws(K):
        return get_switcher("periodic", m, n_byz=4, K=K, seed=seed)

    def cell_loop():
        return [run_dynabro_scan(task.grad_fn, task.params0, opt,
                                 cell_cfgs[n], sws(K), sampler, T, seed=seed,
                                 scan_fn=cell_fns[n])
                for n, _, K in cells]

    def grouped():
        return run_dynabro_scan_sweep(
            task.grad_fn, task.params0, opt, cfg,
            [sws(K) for _, _, K in cells], sampler, T, seed=seed,
            scan_fn=group_fns, aggregators=[(n, kw) for n, kw, _ in cells])

    per_cell = cell_loop()
    per_lane = grouped()
    assert len(per_cell) == len(per_lane) == 16
    for (p_ref, logs_ref, _), (p_lane, logs_lane) in zip(per_cell, per_lane):
        assert logs_ref == logs_lane
        np.testing.assert_allclose(np.asarray(p_ref["x"]),
                                   np.asarray(p_lane["x"]),
                                   rtol=1e-6, atol=1e-7)

    def t_loop():
        outs = cell_loop()
        return (outs[-1][0],)

    def t_grouped():
        outs = grouped()
        return (outs[-1][0],)

    return _time(t_loop, iters), _time(t_grouped, iters)


def main(fast: bool = False):
    iters = 2 if fast else 3
    rows = []
    for T in (64, 256):
        out = run(T, iters=iters, sharded=(T == 256))
        us_legacy, us_scan = out[0], out[1]
        rows.append(f"scan_driver/python_loop_T{T},{us_legacy:.0f},")
        rows.append(f"scan_driver/scan_T{T},{us_scan:.0f},"
                    f"speedup={us_legacy / us_scan:.1f}x")
        if T == 256:
            rows.append(f"scan_driver/sharded_T{T},{out[2]:.0f},"
                        f"overhead={out[2] / us_scan:.2f}x")
    us_loop, us_vmap = run_sweep(iters=iters)
    C = len(SWEEP_KS)
    rows.append(f"scan_driver/sweep_loop_C{C},{us_loop:.0f},")
    rows.append(f"scan_driver/sweep_vmap_C{C},{us_vmap:.0f},"
                f"speedup={us_loop / us_vmap:.1f}x")
    us_groups, us_lanes = run_attack_sweep(iters=iters)
    a, s = len(ATTACK_SPECS), len(ATTACK_KS)
    rows.append(f"scan_driver/sweep_attack_loop_A{a}xS{s},{us_groups:.0f},")
    rows.append(f"scan_driver/sweep_vmap_attacks,{us_lanes:.0f},"
                f"speedup={us_groups / us_lanes:.1f}x")
    us_agg_groups, us_agg_lanes = run_agg_sweep(iters=iters)
    g = len(AGG_SPECS)
    rows.append(f"scan_driver/sweep_agg_loop_G{g},{us_agg_groups:.0f},")
    rows.append(f"scan_driver/sweep_vmap_aggs,{us_agg_lanes:.0f},"
                f"speedup={us_agg_groups / us_agg_lanes:.1f}x")
    us_cells, us_grouped = run_mixed_agg_sweep(iters=iters)
    rows.append(f"scan_driver/sweep_agg_loop,{us_cells:.0f},")
    rows.append(f"scan_driver/sweep_vmap_mixed_aggs,{us_grouped:.0f},"
                f"speedup={us_cells / us_grouped:.1f}x")
    us_seed_loop, us_seed_lanes = run_seed_sweep(iters=iters)
    rows.append(f"scan_driver/sweep_seed_loop_R4,{us_seed_loop:.0f},")
    rows.append(f"scan_driver/sweep_vmap_seeds,{us_seed_lanes:.0f},"
                f"speedup={us_seed_loop / us_seed_lanes:.1f}x")
    us_grid, n_lanes, n_chunks = run_big_grid(iters=1 if fast else 2)
    rows.append(f"scan_driver/grid1024_chunked,{us_grid:.0f},"
                f"lanes={n_lanes};chunks={n_chunks}")
    rows.append(f"scan_driver/recompiles_steady,0,"
                f"recompiles={_STEADY_RECOMPILES}")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
