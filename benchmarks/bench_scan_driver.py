"""Compiled vs Python-loop DynaBRO driver wall-clock (DESIGN.md §5).

Times full ``run_dynabro`` (legacy per-round dispatch) against
``run_dynabro_scan`` (whole loop in one chunked ``lax.scan``) on the
quadratic testbed at T ∈ {64, 256}, steady state (prebuilt step / scan fn,
one warmup run so jit caches are hot; the schedules repeat per seed so the
warmup covers every level the timed run dispatches). Asserts the two drivers
agree bitwise on the final iterate before timing — a benchmark that compares
non-equivalent code is meaningless.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import (
    DynaBROConfig, make_dynabro_scan_fn, make_dynabro_step, run_dynabro,
    run_dynabro_scan,
)
from repro.core.scenarios import make_quadratic_task
from repro.core.switching import get_switcher
from repro.optim.optimizers import sgd


def _time(fn, iters: int):
    fn()  # warmup: compiles + populates per-level jit caches
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(jax.tree.leaves(out[0]))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(T: int, m: int = 9, iters: int = 3, seed: int = 0):
    task = make_quadratic_task()
    cfg = DynaBROConfig(mlmc=MLMCConfig(T=T, m=m, V=3.0, kappa=1.0),
                        aggregator="cwmed", delta=0.45, attack="sign_flip")
    sampler = task.make_sampler(m)
    opt = sgd(2e-2)
    step = make_dynabro_step(task.grad_fn, cfg, opt)
    scan_fn = make_dynabro_scan_fn(task.grad_fn, cfg, opt)

    def legacy():
        sw = get_switcher("periodic", m, n_byz=4, K=20, seed=seed)
        return run_dynabro(task.grad_fn, task.params0, opt, cfg, sw, sampler,
                           T, seed=seed, step=step)

    def scan():
        sw = get_switcher("periodic", m, n_byz=4, K=20, seed=seed)
        return run_dynabro_scan(task.grad_fn, task.params0, opt, cfg, sw,
                                sampler, T, seed=seed, scan_fn=scan_fn)

    p_legacy = legacy()[0]
    p_scan = scan()[0]
    np.testing.assert_array_equal(np.asarray(p_legacy["x"]),
                                  np.asarray(p_scan["x"]))
    us_legacy = _time(legacy, iters)
    us_scan = _time(scan, iters)
    return us_legacy, us_scan


def main(fast: bool = False):
    rows = []
    for T in (64, 256):
        us_legacy, us_scan = run(T, iters=2 if fast else 3)
        rows.append(f"scan_driver/python_loop_T{T},{us_legacy:.0f},")
        rows.append(f"scan_driver/scan_T{T},{us_scan:.0f},"
                    f"speedup={us_legacy / us_scan:.1f}x")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
