"""Figure 2 / Figure 8: Bernoulli(p, D, δmax) switching on classification.

m=25 workers, IPM attack, CWMed aggregation. Configurations from Section 6:
(p=0.01, D=10), (p=0.01, D=50), (p=0.05, D=10), with δmax ∈ {0.72, 0.48}.
With transiently >50% Byzantine workers, momentum and SGD break; DynaBRO's
short stochastic history window recovers.

As in ``bench_periodic``, seeds are replicate lanes of ONE vmapped sweep
dispatch (DESIGN.md §12): dataset + init fixed at the base seed, switcher /
attack-key / batch-index streams folded per replicate. The whole
δmax × (p, D) grid × seeds runs as a single dispatch; momentum baselines
loop per seed with the same stream convention.
"""
from __future__ import annotations

import numpy as np

from benchmarks._clf import make_index_sampler, make_task, seed_stat
from repro.api.session import Session
from repro.api.specs import SweepSpec
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig, run_momentum
from repro.core.switching import get_switcher
from repro.optim.optimizers import sgd

M = 25


def run(T: int = 400, seeds=(0, 1), dmaxes=(0.72, 0.48)):
    base = seeds[0]
    params0, grad_fn, sampler, eval_fn = make_task(M, seed=base)
    cfg = DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=M, V=5.0, option=1, kappa=1.0, j_cap=5),
        aggregator="cwmed", attack="ipm", attack_kwargs={"eps": 0.1})
    sess = Session(cfg, grad_fn=grad_fn, params0=params0, opt=sgd(0.1), m=M,
                   sample_batches=sampler, seed=base,
                   sampler_factory=lambda s: make_index_sampler(M, seed=s))
    grid = [(dmax, p, D) for dmax in dmaxes
            for (p, D) in ((0.01, 10), (0.01, 50), (0.05, 10))]
    spec = SweepSpec(
        switchers=tuple(("bernoulli", dict(p=p, D=D, delta_max=dmax))
                        for dmax, p, D in grid),
        seeds=tuple(seeds))
    outs = sess.sweep(spec, T)
    cells = outs if len(seeds) > 1 else [[cell] for cell in outs]
    # jaxlint: disable=JXL003 -- 2.5 = 5/2 is exact in binary, so T*2.5 is exact; intended grad-budget truncation
    Tm = int(T * 2.5)
    rows = []
    for (dmax, p, D), cell in zip(grid, cells):
        accs = {"dynabro": [eval_fn(pp, T)["test_acc"] for pp, _ in cell],
                "momentum0.9": [], "sgd": []}
        byz_frac = [np.mean([l.n_byz for l in logs]) / M for _, logs in cell]
        for s in seeds:
            sampler_s = make_index_sampler(M, seed=s)
            for beta, tag in ((0.9, "momentum0.9"), (0.0, "sgd")):
                sw = get_switcher("bernoulli", M, p=p, D=D, delta_max=dmax,
                                  seed=s)
                pm, _ = run_momentum(grad_fn, params0, cfg, sw, sampler_s,
                                     Tm, lr=0.05, beta=beta, seed=s)
                accs[tag].append(eval_fn(pm, Tm)["test_acc"])
        for meth, vals in accs.items():
            rows.append((f"p{p}_D{D}_dmax{dmax}/{meth}", vals,
                         float(np.mean(byz_frac))))
    return rows


def main(fast: bool = False):
    rows = run(T=120 if fast else 400, seeds=(0,) if fast else (0, 1),
               dmaxes=(0.72,) if fast else (0.72, 0.48))
    return [f"bernoulli_ipm_cwmed/{n},,{seed_stat('test_acc', vals)}"
            f";mean_byz_frac={b:.2f}" for n, vals, b in rows]


if __name__ == "__main__":
    print("\n".join(main()))
