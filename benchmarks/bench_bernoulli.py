"""Figure 2 / Figure 8: Bernoulli(p, D, δmax) switching on classification.

m=25 workers, IPM attack, CWMed aggregation. Configurations from Section 6:
(p=0.01, D=10), (p=0.01, D=50), (p=0.05, D=10), with δmax ∈ {0.72, 0.48}.
With transiently >50% Byzantine workers, momentum and SGD break; DynaBRO's
short stochastic history window recovers.
"""
from __future__ import annotations

import numpy as np

from benchmarks._clf import make_task
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig, run_dynabro, run_momentum
from repro.core.switching import get_switcher
from repro.optim.optimizers import sgd

M = 25


def run(T: int = 400, seeds=(0, 1), dmaxes=(0.72, 0.48)):
    rows = []
    for dmax in dmaxes:
        for (p, D) in ((0.01, 10), (0.01, 50), (0.05, 10)):
            accs = {"dynabro": [], "momentum0.9": [], "sgd": []}
            byz_frac = []
            for s in seeds:
                params0, grad_fn, sampler, eval_fn = make_task(M, seed=s)
                cfg = DynaBROConfig(
                    mlmc=MLMCConfig(T=T, m=M, V=5.0, option=1, kappa=1.0, j_cap=5),
                    aggregator="cwmed", attack="ipm", attack_kwargs={"eps": 0.1})
                sw = get_switcher("bernoulli", M, p=p, D=D, delta_max=dmax, seed=s)
                pp, logs, _ = run_dynabro(grad_fn, params0, sgd(0.1), cfg, sw,
                                          sampler, T, seed=s)
                accs["dynabro"].append(eval_fn(pp, T)["test_acc"])
                byz_frac.append(np.mean([l.n_byz for l in logs]) / M)
                # jaxlint: disable=JXL003 -- 2.5 = 5/2 is exact in binary, so T*2.5 is exact; intended grad-budget truncation
                Tm = int(T * 2.5)
                for beta, tag in ((0.9, "momentum0.9"), (0.0, "sgd")):
                    sw2 = get_switcher("bernoulli", M, p=p, D=D, delta_max=dmax,
                                       seed=s)
                    pm, _ = run_momentum(grad_fn, params0, cfg, sw2, sampler, Tm,
                                         lr=0.05, beta=beta, seed=s)
                    accs[tag].append(eval_fn(pm, Tm)["test_acc"])
            for meth, vals in accs.items():
                rows.append((f"p{p}_D{D}_dmax{dmax}/{meth}",
                             float(np.mean(vals)), float(np.std(vals)),
                             float(np.mean(byz_frac))))
    return rows


def main(fast: bool = False):
    rows = run(T=120 if fast else 400, seeds=(0,) if fast else (0, 1),
               dmaxes=(0.72,) if fast else (0.72, 0.48))
    return [f"bernoulli_ipm_cwmed/{n},,test_acc={m:.3f}+-{s:.3f};mean_byz_frac={b:.2f}"
            for n, m, s, b in rows]


if __name__ == "__main__":
    print("\n".join(main()))
