"""Model-zoo driver wall-clock + peak-memory (DESIGN.md §9).

Times the unified zoo path — a reduced real transformer through
``run_dynabro_scan`` — stacked vs microbatched, and lowers both segment fns
to compare XLA's ``memory_analysis().temp_size_in_bytes``. The gated claim
(benchmarks/check_regression.py) is the microbatch streaming contract: the
per-round grad-accumulation scan must never materialize the full
(m, n_max, d) per-worker gradient stack, so its peak temp bytes stay under
one f32 copy of that stack (the stacked path's floor). Rows:

* ``model_zoo/scan_T{T}`` / ``model_zoo/microbatch_T{T}`` — steady-state
  wall-clock per driver call, ``rounds_per_s`` derived.
* ``model_zoo/stacked_mem`` / ``model_zoo/microbatch_mem`` — compiled temp
  bytes (the us field carries MB), ``vs_stack`` = temp bytes / one full
  (m, n_max, d) f32 stack. The microbatch row is gated ``<= 1.0x`` and
  additionally asserted here — a benchmark that measures a path which
  silently materializes the stack would gate nothing.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import (
    DynaBROConfig, make_dynabro_scan_fn, run_dynabro_scan,
)
from repro.core.switching import get_switcher
from repro.models.zoo import make_zoo_task
from repro.optim.optimizers import sgd

M, UB, SEQ, D_MODEL, J_CAP = 8, 1, 16, 64, 3


def _time(fn, iters: int):
    fn()  # warmup: compiles + populates the jit cache
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(jax.tree.leaves(out[0]))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _temp_bytes(scan_fn, carry, xs) -> int:
    return scan_fn.lower(carry, xs).compile().memory_analysis() \
        .temp_size_in_bytes


def run(T: int, iters: int):
    task, cfg = make_zoo_task("smollm-360m", seq_len=SEQ, d_model=D_MODEL,
                              unit_batch=UB)
    dcfg = DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=M, V=3.0, kappa=1.0, j_cap=J_CAP),
        aggregator="cwtm", delta=0.3, attack="sign_flip")
    opt = sgd(0.05)
    sampler = task.make_sampler(M)
    fn_stacked = make_dynabro_scan_fn(task.grad_fn, dcfg, opt)
    fn_mb = make_dynabro_scan_fn(task.grad_fn, dcfg, opt, microbatch=True)

    def drive(fn, microbatch):
        sw = get_switcher("periodic", M, n_byz=2, K=4)
        return run_dynabro_scan(task.grad_fn, task.params0, opt, dcfg, sw,
                                sampler, T, seed=3, scan_fn=fn,
                                microbatch=microbatch)

    # both paths must agree (fp tolerance: summation order differs) before
    # either is timed or measured
    p_st = drive(fn_stacked, False)[0]
    p_mb = drive(fn_mb, True)[0]
    for a, b in zip(jax.tree.leaves(p_st), jax.tree.leaves(p_mb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)

    us_st = _time(lambda: drive(fn_stacked, False), iters)
    us_mb = _time(lambda: drive(fn_mb, True), iters)

    # lower the T-round segment exactly as the driver shapes it
    n_max = 2 ** J_CAP
    carry = (task.params0, opt.init(task.params0))
    xs = (jnp.ones((T,), jnp.int32),
          {"tokens": jnp.zeros((T, M, n_max, UB, SEQ), jnp.int32),
           "labels": jnp.zeros((T, M, n_max, UB, SEQ), jnp.int32)},
          jnp.zeros((T, n_max, M), bool),
          jnp.zeros((T, 2), jnp.uint32))
    mem_st = _temp_bytes(fn_stacked, carry, xs)
    mem_mb = _temp_bytes(fn_mb, carry, xs)
    d = sum(l.size for l in jax.tree.leaves(task.params0))
    stack_bytes = M * n_max * d * 4  # one full (m, n_max, d) f32 grad stack
    assert mem_mb < stack_bytes, (
        f"microbatched segment temp bytes {mem_mb} >= one (m, n_max, d) "
        f"stack {stack_bytes} — the streaming path materialized the stack")
    return us_st, us_mb, mem_st, mem_mb, stack_bytes


def main(fast: bool = False):
    T = 8 if fast else 16
    iters = 2 if fast else 3
    us_st, us_mb, mem_st, mem_mb, stack = run(T, iters)
    return [
        f"model_zoo/scan_T{T},{us_st:.0f},rounds_per_s={T / us_st * 1e6:.1f}",
        f"model_zoo/microbatch_T{T},{us_mb:.0f},"
        f"rounds_per_s={T / us_mb * 1e6:.1f}",
        f"model_zoo/stacked_mem,{mem_st / 1e6:.1f},"
        f"vs_stack={mem_st / stack:.2f}x",
        f"model_zoo/microbatch_mem,{mem_mb / 1e6:.1f},"
        f"vs_stack={mem_mb / stack:.2f}x",
    ]


if __name__ == "__main__":
    print("\n".join(main()))
