"""Aggregation-service throughput (DESIGN.md §10).

Streams a full T-round, 16-worker update stream through the serve stack —
ring buffer ingress, pending-table assembly, jitted session step — with
prebuilt payloads and hot jit caches, against the offline compiled scan
driver on the same schedule as the no-service ceiling. Asserts the streamed
result is bitwise-identical to the offline driver before timing (a
throughput number for a wrong stream is meaningless).

``serve/sustained_m16`` feeds the CI floor gate in check_regression.py:
its ``updates_per_sec`` must not collapse — the serve loop's per-round
overhead (thread handoff, re-stack, mask copy) has to stay bounded relative
to the compiled step it drives.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.api import build_session
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig
from repro.core.scenarios import make_quadratic_task
from repro.core.switching import get_switcher
from repro.lint.runtime import recompile_guard
from repro.optim.optimizers import adagrad_norm
from repro.serve import AggregationServer, ServeConfig, SimulatedWorkers
from repro.serve.client import worker_payloads

M, SEED = 16, 3


def _session(T):
    task = make_quadratic_task()
    cfg = DynaBROConfig(mlmc=MLMCConfig(T=T, m=M, V=3.0, kappa=1.0, j_cap=2),
                        aggregator="cwmed", delta=0.4, attack="sign_flip")
    return build_session(
        cfg, task, switcher=get_switcher("periodic", M, n_byz=4, K=5,
                                         seed=SEED),
        opt=adagrad_norm(2e-2), seed=SEED)


def _stream(sess, T, payloads):
    server = AggregationServer(sess, T, ServeConfig(capacity=512,
                                                    lookahead_rounds=8))
    server.start()
    t0 = time.perf_counter()
    workers = SimulatedWorkers(server, payloads).start()
    assert workers.join(timeout=600.0) and not workers.failures
    assert server.join(timeout=600.0), server.snapshot()
    wall = time.perf_counter() - t0
    server.close()
    assert server.error is None
    return server.params, wall


def main(fast: bool = False):
    T = 64 if fast else 256
    sess = _session(T)
    payloads = worker_payloads(sess, T)

    # warm every jit cache: the length-1 step segment via a one-round
    # stream (then drain — the server still expects T rounds), the whole-T
    # segment via one offline run
    warm = AggregationServer(sess, T)
    warm.start()
    SimulatedWorkers(warm, [payloads[0]]).start().join(timeout=600.0)
    deadline = time.monotonic() + 600.0
    while warm.round < 1 and time.monotonic() < deadline:
        time.sleep(0.005)
    assert warm.round >= 1, warm.snapshot()
    warm.close()
    params_ref, _, _ = sess.run(T)
    t0 = time.perf_counter()
    params_ref, _, _ = sess.run(T)
    jax.block_until_ready(params_ref["x"])
    offline_wall = time.perf_counter() - t0

    # the timed stream is post-warmup steady state: the length-1 step segment
    # and the whole-T offline segment are both hot, so ANY compile inside the
    # window is churn — the count feeds the serve 0-recompile CI gate
    # (DESIGN.md §11); compiles on the server's consumer thread count too
    with recompile_guard("bench_serve timed stream", action="count") as g:
        params, wall = _stream(sess, T, payloads)
    for a, b in zip(np.asarray(params["x"]), np.asarray(params_ref["x"])):
        assert a == b, "served stream diverged from the offline driver"

    ups = M * T / wall
    return [
        f"serve/sustained_m16,{wall / T * 1e6:.0f},"
        f"updates_per_sec={ups:.0f};rounds={T};"
        f"overhead={wall / offline_wall:.2f}x",
        f"serve/offline_scan_m16,{offline_wall / T * 1e6:.0f},"
        f"rounds_per_sec={T / offline_wall:.0f}",
        f"serve/recompiles_steady,0,recompiles={g.count}",
    ]


if __name__ == "__main__":
    for row in main(fast=True):
        print(row)
