"""Aggregator micro-benchmarks: Pallas kernels (interpret mode on CPU;
compiled on TPU) vs the pure-jnp references, plus the full tree aggregators
on a model-sized gradient stack. On-CPU numbers are correctness-path timings;
the derived column reports bytes processed per call."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._clf import timed
from repro.core.aggregators import get_aggregator
from repro.kernels.ops import cwmed_op, cwtm_op, pairwise_sqdist_op
from repro.kernels.ref import cwmed_ref, cwtm_ref, pairwise_sqdist_ref


def main(fast: bool = False):
    out = []
    m, d = 16, (1 << 16 if fast else 1 << 20)
    x = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)
    mb = m * d * 4 / 1e6
    for name, fn in [("cwmed_kernel", lambda: cwmed_op(x)),
                     ("cwmed_ref", lambda: jax.jit(cwmed_ref)(x)),
                     ("cwtm_kernel", lambda: cwtm_op(x, 4)),
                     ("cwtm_ref", lambda: jax.jit(lambda a: cwtm_ref(a, 4))(x)),
                     ("pairwise_kernel", lambda: pairwise_sqdist_op(x)),
                     ("pairwise_ref", lambda: jax.jit(pairwise_sqdist_ref)(x))]:
        _, us = timed(fn, iters=2 if "kernel" in name else 5)
        out.append(f"aggregators/{name},{us:.0f},MB_in={mb:.1f}")
    # tree aggregators on a gradient-like pytree
    tree = {"w1": jax.random.normal(jax.random.PRNGKey(1), (m, 256, 256)),
            "w2": jax.random.normal(jax.random.PRNGKey(2), (m, 256, 64)),
            "b": jax.random.normal(jax.random.PRNGKey(3), (m, 256))}
    for name in ("cwmed", "cwtm", "krum", "geomed", "nnm+cwmed"):
        agg = get_aggregator(name, delta=0.25)
        f = jax.jit(agg.tree)
        _, us = timed(f, tree, iters=5)
        out.append(f"aggregators/tree_{name},{us:.0f},leaves=3;m={m}")
    return out


if __name__ == "__main__":
    print("\n".join(main()))
