"""Aggregator micro-benchmarks: Pallas kernels (interpret mode on CPU;
compiled on TPU) vs the pure-jnp references, plus the full engine rules on a
model-sized gradient stack, per backend. On-CPU numbers are correctness-path
timings; the derived column reports bytes processed per call. Each ref/pallas
pair is asserted numerically equal before it is timed, so a kernel regression
fails the benchmark instead of silently reporting a fast wrong answer."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._clf import timed
from repro.core.aggregators import get_aggregator
from repro.kernels.ops import (cwmed_op, cwtm_op, pairwise_sqdist_op,
                               weighted_combine_op)
from repro.kernels.ref import (cwmed_ref, cwtm_ref, pairwise_sqdist_ref,
                               weighted_combine_ref)

TREE_RULES = ("mean", "cwmed", "cwtm", "krum", "geomed", "nnm+cwmed")


def _assert_close(a, b, name, tol=2e-4):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    scale = np.abs(b).max() + 1e-9
    err = np.abs(a - b).max() / scale
    assert err < tol, f"ref/pallas parity broke for {name}: rel err {err:.2e}"


def _model_stack(m):
    """Model-shaped gradient pytree, ~4.3M params per worker."""
    return {
        "embed": jax.random.normal(jax.random.PRNGKey(1), (m, 4096, 512)),
        "blocks": {
            "wqkv": jax.random.normal(jax.random.PRNGKey(2), (m, 2, 512, 1536)),
            "norm": jax.random.normal(jax.random.PRNGKey(3), (m, 2, 512)),
        },
        "head": jax.random.normal(jax.random.PRNGKey(4), (m, 512, 1024)),
    }


def main(fast: bool = False):
    out = []
    m, d = 16, (1 << 16 if fast else 1 << 20)
    x = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(5), (1, m)))
    mb = m * d * 4 / 1e6
    kernel_pairs = [
        ("cwmed", lambda: cwmed_op(x), lambda: jax.jit(cwmed_ref)(x)),
        ("cwtm", lambda: cwtm_op(x, 4), lambda: jax.jit(lambda a: cwtm_ref(a, 4))(x)),
        ("pairwise", lambda: pairwise_sqdist_op(x),
         lambda: jax.jit(pairwise_sqdist_ref)(x)),
        ("combine", lambda: weighted_combine_op(x, w),
         lambda: jax.jit(weighted_combine_ref)(x, w)),
    ]
    for name, kfn, rfn in kernel_pairs:
        _assert_close(kfn(), rfn(), name)
        _, kus = timed(kfn, iters=2)
        _, rus = timed(rfn, iters=5)
        out.append(f"aggregators/{name}_kernel,{kus:.0f},MB_in={mb:.1f}")
        out.append(f"aggregators/{name}_ref,{rus:.0f},MB_in={mb:.1f}")
    # engine rules on a model-sized gradient stack, per backend
    mt = 4 if fast else 16
    tree = _model_stack(mt)
    nbytes = sum(l.size * 4 for l in jax.tree.leaves(tree)) / 1e6
    for name in TREE_RULES:
        results = {}
        for backend in ("ref",) if fast else ("ref", "pallas"):
            agg = get_aggregator(name, delta=0.25, backend=backend)
            f = jax.jit(agg.tree)
            results[backend], us = timed(f, tree, iters=2)
            out.append(f"aggregators/tree_{name}_{backend},{us:.0f},"
                       f"MB_in={nbytes:.0f};m={mt}")
        if "pallas" in results:
            for rl, pl in zip(jax.tree.leaves(results["ref"]),
                              jax.tree.leaves(results["pallas"])):
                _assert_close(pl, rl, f"tree_{name}")
    return out


if __name__ == "__main__":
    print("\n".join(main()))
