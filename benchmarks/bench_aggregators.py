"""Aggregator micro-benchmarks: the engine's size-dispatched path (what the
driver actually runs — ``agg_engine.dispatch_backend`` picks pallas or ref
from the bytes moved and the kernel kind) vs the forced pure-jnp references,
the fused one-pass kernel vs a split three-dispatch pipeline, and the full
engine rules on a model-sized gradient stack, per backend. On-CPU numbers
are correctness-path timings (kernels run in interpret mode); the derived
column reports the bytes-moved model per call — ``MB_in``/``MB_out`` are the
ideal once-through traffic, ``MB_moved`` is what the implementation actually
streams (the fused one-pass reads the gradient stack once; the split
pipeline re-reads it per stage), and ``benchmarks/roofline.py --check``
gates kernel rows' achieved-vs-ideal ratio. Each contender/ref pair is
asserted numerically equal before it is timed, so a kernel regression fails
the benchmark instead of silently reporting a fast wrong answer."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._clf import timed
from repro.core import agg_engine as E
from repro.core.aggregators import get_aggregator
from repro.kernels.ops import fused_op
from repro.kernels.ref import (cwmed_ref, cwtm_ref, pairwise_sqdist_ref,
                               weighted_combine_ref)

TREE_RULES = ("mean", "cwmed", "cwtm", "krum", "geomed", "nnm+cwmed")
TRIM = 4


def _assert_close(a, b, name, tol=2e-4):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    scale = np.abs(b).max() + 1e-9
    err = np.abs(a - b).max() / scale
    assert err < tol, f"ref/pallas parity broke for {name}: rel err {err:.2e}"


def _mb(*shapes):
    return sum(4 * int(np.prod(s)) for s in shapes) / 1e6


def _best(fn, *args, rounds=3, iters=5):
    """Best-of-rounds us/call: interpret-mode pallas and ~ms-scale jnp calls
    both jitter ±30% on a busy host; the min over a few timed rounds is the
    stable statistic the vs_* ratio gates need."""
    return min(timed(fn, *args, iters=iters)[1] for _ in range(rounds))


def _model_stack(m):
    """Model-shaped gradient pytree, ~4.3M params per worker."""
    return {
        "embed": jax.random.normal(jax.random.PRNGKey(1), (m, 4096, 512)),
        "blocks": {
            "wqkv": jax.random.normal(jax.random.PRNGKey(2), (m, 2, 512, 1536)),
            "norm": jax.random.normal(jax.random.PRNGKey(3), (m, 2, 512)),
        },
        "head": jax.random.normal(jax.random.PRNGKey(4), (m, 512, 1024)),
    }


def main(fast: bool = False):
    out = []
    m, d = 16, (1 << 16 if fast else 1 << 20)
    x = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)
    w1 = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(5), (1, m)))
    nbytes = 4 * x.size
    # engine primitives: the auto-dispatched path vs the forced reference
    prim = [
        ("cwmed", "sort",
         jax.jit(lambda a: E.cw_median(a, backend="auto")),
         jax.jit(lambda a: E.cw_median(a, backend="ref")),
         _mb((m, d)), _mb((d,))),
        ("cwtm", "sort",
         jax.jit(lambda a: E.cw_trimmed_mean(a, TRIM, backend="auto")),
         jax.jit(lambda a: E.cw_trimmed_mean(a, TRIM, backend="ref")),
         _mb((m, d)), _mb((d,))),
        ("pairwise", "matmul",
         jax.jit(lambda a: E.pairwise_sqdist(a, backend="auto")),
         jax.jit(lambda a: E.pairwise_sqdist(a, backend="ref")),
         _mb((m, d)), _mb((m, m))),
        ("combine", "matmul",
         jax.jit(lambda a, b: E.weighted_combine(a, b, backend="auto")),
         jax.jit(lambda a, b: E.weighted_combine(a, b, backend="ref")),
         _mb((m, d), (1, m)), _mb((1, d))),
    ]
    sep_us = {}
    for name, kind, kfn, rfn, mb_in, mb_out in prim:
        args = (x, w1) if name == "combine" else (x,)
        _assert_close(kfn(*args), rfn(*args), name)
        iters = 3 if kind == "sort" else 20
        kus = _best(kfn, *args, iters=iters)
        rus = _best(rfn, *args, iters=iters)
        sep_us[name] = kus
        impl = E.dispatch_backend("auto", kind=kind, nbytes=nbytes)
        out.append(f"aggregators/{name}_kernel,{kus:.0f},"
                   f"MB_in={mb_in:.2f};MB_out={mb_out:.2f};"
                   f"MB_moved={mb_in + mb_out:.2f};impl={impl};"
                   f"vs_ref={rus / kus:.2f}x")
        out.append(f"aggregators/{name}_ref,{rus:.0f},MB_in={mb_in:.2f}")
    # fused reductions vs the (now fused-backed) separate dispatched path
    fused_single = [
        ("fused_cwmed", jax.jit(lambda a: fused_op(a, reduce="med")),
         cwmed_ref(x), "cwmed"),
        ("fused_cwtm", jax.jit(lambda a: fused_op(a, reduce="tm", trim=TRIM)),
         cwtm_ref(x, TRIM), "cwtm"),
    ]
    for name, fn, ref, sep in fused_single:
        _assert_close(fn(x)["reduce"], ref, name)
        us = _best(fn, x, iters=3)
        out.append(f"aggregators/{name}_kernel,{us:.0f},"
                   f"MB_in={_mb((m, d)):.2f};MB_out={_mb((d,)):.2f};"
                   f"MB_moved={_mb((m, d), (d,)):.2f};impl=pallas;"
                   f"vs_sep={sep_us[sep] / us:.2f}x")
    # the full one-pass round (combine + trimmed reduce + pairwise in ONE
    # dispatch, x streamed once) vs the same outputs as three kernel calls
    wm = jax.random.uniform(jax.random.PRNGKey(6), (m, m), jnp.float32) + 0.1
    wm = wm / wm.sum(axis=1, keepdims=True)
    one = jax.jit(lambda a, b: fused_op(a, b, reduce="tm", trim=TRIM,
                                        pairwise=True, combine=True))

    def _split_fn(a, b):
        y = fused_op(a, b, combine=True)["combine"]
        red = fused_op(y, reduce="tm", trim=TRIM)["reduce"]
        pw = fused_op(a, pairwise=True)["pairwise"]
        return {"combine": y, "reduce": red, "pairwise": pw}

    split = jax.jit(_split_fn)
    got, want = one(x, wm), split(x, wm)
    mixed = weighted_combine_ref(x, wm)
    _assert_close(got["combine"], mixed, "fused_onepass_combine")
    _assert_close(got["reduce"], cwtm_ref(mixed, TRIM), "fused_onepass_reduce")
    _assert_close(got["pairwise"], pairwise_sqdist_ref(x), "fused_onepass_pw")
    for key in ("combine", "reduce", "pairwise"):
        _assert_close(got[key], want[key], f"onepass_vs_split_{key}")
    one_us = _best(one, x, wm, iters=2)
    split_us = _best(split, x, wm, iters=2)
    mb_in = _mb((m, d), (m, m))
    mb_out = _mb((m, d), (d,), (m, m))
    # split traffic: x read by combine AND pairwise, w once, y written by
    # combine then re-read by the reduce stage, plus the shared outputs
    mb_split = _mb((m, d), (m, d), (m, m), (m, d)) + mb_out
    out.append(f"aggregators/fused_onepass_kernel,{one_us:.0f},"
               f"MB_in={mb_in:.2f};MB_out={mb_out:.2f};"
               f"MB_moved={mb_in + mb_out:.2f};impl=pallas;"
               f"vs_split={split_us / one_us:.2f}x")
    out.append(f"aggregators/fused_onepass_split,{split_us:.0f},"
               f"MB_in={mb_in:.2f};MB_out={mb_out:.2f};"
               f"MB_moved={mb_split:.2f}")
    # engine rules on a model-sized gradient stack, per backend
    mt = 4 if fast else 16
    tree = _model_stack(mt)
    tree_mb = sum(l.size * 4 for l in jax.tree.leaves(tree)) / 1e6
    for name in TREE_RULES:
        results = {}
        for backend in ("ref",) if fast else ("ref", "pallas"):
            agg = get_aggregator(name, delta=0.25, backend=backend)
            f = jax.jit(agg.tree)
            results[backend], us = timed(f, tree, iters=2)
            out.append(f"aggregators/tree_{name}_{backend},{us:.0f},"
                       f"MB_in={tree_mb:.0f};m={mt}")
        if "pallas" in results:
            for rl, pl in zip(jax.tree.leaves(results["ref"]),
                              jax.tree.leaves(results["pallas"])):
                _assert_close(pl, rl, f"tree_{name}")
    return out


if __name__ == "__main__":
    print("\n".join(main()))
