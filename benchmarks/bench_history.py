"""Table 1: history-window dependence of Byzantine-robust methods.

Measured (not asserted): MLMC per-round per-worker gradient evaluations
(expected O(log T), stochastic window 2^J with E[window] = O(log T)) vs the
deterministic windows of ByzantineSGD (T), SafeguardSGD (T^{5/8}) and
worker-momentum (1/(1-β) ≈ √T).
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.mlmc import round_cost, sample_level


def run(T: int = 4096, n: int = 50_000):
    rng = np.random.default_rng(0)
    jmax = int(math.log2(T))
    js = [sample_level(rng, jmax) for _ in range(n)]
    # beyond-cap draws (j = jmax+1) cost 1 and fall back to the unit batch —
    # round_cost, the drivers' accounting; the window they realize is 1 unit
    cost = float(np.mean([round_cost(j, jmax) for j in js]))
    window = float(np.mean([2.0 ** j if j <= jmax else 1.0 for j in js]))
    beta = 1.0 - 1.0 / math.sqrt(T)
    rows = [
        ("byzantine_sgd", T, T, "deterministic"),
        ("safeguard_sgd", T, round(T ** (5 / 8)), "deterministic"),
        ("worker_momentum", T, round(1 / (1 - beta)), "deterministic"),
        ("mlmc_ours_measured", round(cost * T), round(window), "stochastic"),
    ]
    derived = [f"theory: E[cost/round]=1+1.5*log2(T)={1 + 1.5 * jmax:.1f}, measured={cost:.2f}"]
    return rows, derived


def main(fast: bool = False):
    rows, derived = run()
    out = [f"history_table1/{n},,per_worker_cost={c};window={w};type={k}"
           for n, c, w, k in rows]
    return out + [f"history_table1/check,,{derived[0]}"]


if __name__ == "__main__":
    print("\n".join(main()))
