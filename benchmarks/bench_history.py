"""Table 1: history-window dependence of Byzantine-robust methods.

Measured (not asserted): MLMC per-round per-worker gradient evaluations
(expected O(log T), stochastic window 2^J with E[window] = O(log T)) vs the
deterministic windows of ByzantineSGD (T), SafeguardSGD (T^{5/8}) and
worker-momentum (1/(1-β) ≈ √T).
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.mlmc import expected_cost, sample_level


def run(T: int = 4096, n: int = 50_000):
    rng = np.random.default_rng(0)
    jmax = int(math.log2(T))
    js = [min(sample_level(rng, jmax), jmax) for _ in range(n)]
    cost = float(np.mean([expected_cost(j) for j in js]))
    window = float(np.mean([2.0 ** j for j in js]))
    beta = 1.0 - 1.0 / math.sqrt(T)
    rows = [
        ("byzantine_sgd", T, T, "deterministic"),
        ("safeguard_sgd", T, round(T ** (5 / 8)), "deterministic"),
        ("worker_momentum", T, round(1 / (1 - beta)), "deterministic"),
        ("mlmc_ours_measured", round(cost * T), round(window), "stochastic"),
    ]
    derived = [f"theory: E[cost/round]=1+1.5*log2(T)={1 + 1.5 * jmax:.1f}, measured={cost:.2f}"]
    return rows, derived


def main(fast: bool = False):
    rows, derived = run()
    out = [f"history_table1/{n},,per_worker_cost={c};window={w};type={k}"
           for n, c, w, k in rows]
    return out + [f"history_table1/check,,{derived[0]}"]


if __name__ == "__main__":
    print("\n".join(main()))
