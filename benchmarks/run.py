"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``--fast`` shrinks rounds/seeds;
the full run reproduces the qualitative claims of Section 6.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

MODULES = [
    "benchmarks.bench_history",        # Table 1
    "benchmarks.bench_mlmc",           # Lemma 3.1
    "benchmarks.bench_aggregators",    # kernels micro
    "benchmarks.bench_momentum_fails",  # Fig 3/4 (App. E)
    "benchmarks.bench_periodic",       # Fig 1/5
    "benchmarks.bench_bernoulli",      # Fig 2/8
    "benchmarks.bench_failsafe",       # Eq. 6 / Thm 4.1 ablation
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None, help="substring filter on module")
    args, _ = ap.parse_known_args()
    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            rows = mod.main(fast=args.fast)
            for r in rows:
                print(r, flush=True)
            print(f"{mod_name},{(time.time()-t0)*1e6:.0f},module_wall_s="
                  f"{time.time()-t0:.1f}", flush=True)
        except Exception as e:  # keep the suite going, report at the end
            failures += 1
            print(f"{mod_name},,ERROR={type(e).__name__}:{e}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
