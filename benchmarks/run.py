"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``--fast`` shrinks rounds/seeds;
the full run reproduces the qualitative claims of Section 6. ``--json``
additionally writes the rows to ``BENCH_<platform>.json`` in the repo root so
the perf trajectory is tracked across PRs (CI uploads it as an artifact).
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time

# allow both `python -m benchmarks.run` and `python benchmarks/run.py`
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

MODULES = [
    "benchmarks.bench_history",        # Table 1
    "benchmarks.bench_mlmc",           # Lemma 3.1
    "benchmarks.bench_aggregators",    # kernels micro
    "benchmarks.bench_scan_driver",    # compiled vs Python-loop driver
    "benchmarks.bench_model_zoo",      # unified zoo driver + memory gate
    "benchmarks.bench_momentum_fails",  # Fig 3/4 (App. E)
    "benchmarks.bench_periodic",       # Fig 1/5
    "benchmarks.bench_bernoulli",      # Fig 2/8
    "benchmarks.bench_failsafe",       # Eq. 6 / Thm 4.1 ablation
    "benchmarks.bench_serve",          # aggregation service throughput
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None, help="substring filter on module")
    ap.add_argument("--json", action="store_true",
                    help="also write rows to BENCH_<platform>.json")
    args, _ = ap.parse_known_args()
    print("name,us_per_call,derived")
    failures = 0
    all_rows = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            rows = mod.main(fast=args.fast)
            for r in rows:
                print(r, flush=True)
            all_rows.extend(rows)
            print(f"{mod_name},{(time.time()-t0)*1e6:.0f},module_wall_s="
                  f"{time.time()-t0:.1f}", flush=True)
        except Exception as e:  # keep the suite going, report at the end
            failures += 1
            print(f"{mod_name},,ERROR={type(e).__name__}:{e}", file=sys.stderr)
    if args.json:
        import jax
        path = os.path.join(os.path.dirname(__file__), "..",
                            f"BENCH_{jax.default_backend()}.json")
        recs = []
        for r in all_rows:
            name, us, derived = (r.split(",", 2) + ["", ""])[:3]
            recs.append({"name": name, "us_per_call": float(us) if us else None,
                         "derived": derived})
        with open(path, "w") as f:
            json.dump({"fast": args.fast, "rows": recs}, f, indent=1)
        print(f"# wrote {os.path.abspath(path)}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
