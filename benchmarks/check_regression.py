"""CI perf gate: assert the BENCH json holds the recorded speedups.

Usage: ``python benchmarks/check_regression.py [BENCH_cpu.json]``

Reads the rows written by ``benchmarks/run.py --json`` and enforces one
threshold per gated row (DESIGN.md §8). Thresholds are deliberately looser
than the numbers recorded on dev hardware — CI smokes on shared 2-core
runners — but tight enough that a real regression (a re-trace per round, a
de-vmapped sweep, a sharding wrapper gone quadratic) trips the gate.

Exit code 0 = all gates pass; 1 = a row is missing, unparseable, or off its
bound, with a message naming the row, the observed value and the threshold.
"""

from __future__ import annotations

import json
import os
import sys

# (row name, derived-field key, bound, direction). ">=": the observed value
# must reach the bound (a speedup we must keep); "<=": it must stay under it
# (an overhead that must stay marginal). Dev-hardware numbers in comments.
GATES = [
    # steady-state compiled loop vs per-round dispatch (~6x dev)
    ("scan_driver/scan_T256", "speedup", 1.5, ">="),
    # shard_map substrate on a 1-device worker mesh (~1.0x dev)
    ("scan_driver/sharded_T256", "overhead", 1.5, "<="),
    # vmapped scenario sweep vs per-cell compiled loop (~6-13x dev)
    ("scan_driver/sweep_vmap_C8", "speedup", 2.0, ">="),
    # attack-lane-batched sweep vs one vmapped call per attack group (~3x dev)
    ("scan_driver/sweep_vmap_attacks", "speedup", 2.0, ">="),
    # whole 4x4x4 grid in ONE dispatch (aggregator axis = CWTM delta lanes)
    # vs one vmapped call per aggregator group (~2x dev)
    ("scan_driver/sweep_vmap_aggs", "speedup", 1.5, ">="),
    # MIXED-rule 4-rule x 4-switcher grid: branch-homogeneous lane grouping
    # (one vmapped dispatch per distinct rule) vs the per-cell compiled
    # loop (~4x dev) — the grid shape that used to be break-even
    ("scan_driver/sweep_vmap_mixed_aggs", "speedup", 1.5, ">="),
    # seed-replicate lanes (R=4 in one dispatch, ONE batch schedule) vs the
    # pre-replicate shape: one single-lane sweep per (cell, seed), paying
    # C*R host-side batch schedules (~3x dev, DESIGN.md §12)
    ("scan_driver/sweep_vmap_seeds", "speedup", 1.5, ">="),
    # size-dispatched engine primitives vs forced references. Sort-kernel
    # rows dispatch to pallas and must keep a real win (~3.5-4.5x dev);
    # matmul rows dispatch to ref below the TPU threshold, so their ratio
    # is ~1.0x by construction and the bound only allows measurement noise
    # (the old always-pallas rows lost 6-45x here).
    ("aggregators/cwmed_kernel", "vs_ref", 1.5, ">="),
    ("aggregators/cwtm_kernel", "vs_ref", 1.5, ">="),
    ("aggregators/pairwise_kernel", "vs_ref", 0.8, ">="),
    ("aggregators/combine_kernel", "vs_ref", 0.8, ">="),
    # fused single-rule reductions vs the dispatched separate path (~1.0x —
    # the separate path IS the fused kernel now; the gate pins the identity)
    ("aggregators/fused_cwmed_kernel", "vs_sep", 0.8, ">="),
    ("aggregators/fused_cwtm_kernel", "vs_sep", 0.8, ">="),
    # combine + trimmed reduce + pairwise in ONE dispatch, gradient stack
    # streamed once, vs the same outputs as three kernel calls (~2.5x dev)
    ("aggregators/fused_onepass_kernel", "vs_split", 1.5, ">="),
    # unified model-zoo driver, microbatched streaming (DESIGN.md §9): the
    # compiled segment's temp bytes must stay under ONE full (m, n_max, d)
    # f32 per-worker gradient stack — the no-materialization contract
    # (~0.55x dev; the stacked path sits at ~1.6x)
    ("model_zoo/microbatch_mem", "vs_stack", 1.0, "<="),
    # aggregation service: sustained 16-worker streamed updates/sec through
    # ring -> pending table -> jitted step (DESIGN.md §10). ~6300/s dev; the
    # floor only catches a collapse of the serve loop's per-round overhead
    # on the 2-core CI runners, not hardware variance.
    ("serve/sustained_m16", "updates_per_sec", 250.0, ">="),
    # steady-state recompile gates (DESIGN.md §11): after warmup, the timed
    # bench loops and the serve consumer must ride the jit cache — ONE
    # compile inside a timed window is a silent 10x, so the bound is zero
    # (counted via jax.monitoring by repro.lint.runtime.recompile_guard)
    ("scan_driver/recompiles_steady", "recompiles", 0.0, "<="),
    ("serve/recompiles_steady", "recompiles", 0.0, "<="),
]


# full-mode accuracy floors (DESIGN.md §12). Checked against the conservative
# edge of the error bar, mean - 2*stderr: a row passes only when its whole
# ~95% interval clears the floor, so a lucky seed can't hide a regression.
# Floors sit far below the recorded ~0.83-0.86 accuracies — they catch a
# collapsed run (diverged optimizer, broken aggregation), not seed noise.
ACC_GATES = [
    ("periodic_sf_cwtm/K=5/dynabro", 0.6),
    ("bernoulli_ipm_cwmed/p0.01_D10_dmax0.72/dynabro", 0.6),
]


def _metric(derived: str, key: str) -> float:
    """Parse ``key=<float>x`` out of a row's derived field."""
    if f"{key}=" not in derived:
        raise ValueError(f"no '{key}=' in derived field {derived!r}")
    return float(derived.split(f"{key}=")[1].split(";")[0].rstrip("x"))


def _seed_metric(derived: str, key: str):
    """Parse ``key=<mean>[+-<std>]`` plus ``n_seeds=<n>`` -> (mean, std, n).

    The ``+-`` is present only for n_seeds >= 2 (the ISSUE-10 contract:
    single-seed rows carry no spread); ``n_seeds`` itself is mandatory."""
    if f"{key}=" not in derived:
        raise ValueError(f"no '{key}=' in derived field {derived!r}")
    frag = derived.split(f"{key}=")[1].split(";")[0]
    mean_s, _, std_s = frag.partition("+-")
    if "n_seeds=" not in derived:
        raise ValueError(f"no 'n_seeds=' in derived field {derived!r}")
    n = int(derived.split("n_seeds=")[1].split(";")[0])
    return float(mean_s), float(std_s or 0.0), n


def _check_stats(rows: dict, fast: bool) -> int:
    """Full-mode statistics gates: replication metadata plus accuracy floors.

    Every accuracy row must carry ``n_seeds``; in a full (non-fast) run it
    must report n_seeds >= 2 — a single-seed accuracy has no error bar and
    cannot be compared as mean - 2*stderr. Fast smokes run one seed by
    design, so only the metadata requirement applies there."""
    failures = 0
    for name, row in sorted(rows.items()):
        derived = row.get("derived") or ""
        if "test_acc=" not in derived:
            continue
        try:
            _, _, n = _seed_metric(derived, "test_acc")
        except ValueError as e:
            print(f"FAIL: row '{name}': {e}")
            failures += 1
            continue
        if not fast and n < 2:
            print(f"FAIL: row '{name}': full-mode accuracy from n_seeds={n} "
                  f"— replicate over >= 2 seeds for an honest error bar")
            failures += 1
    if fast:
        print("ok: accuracy replication (fast mode: n_seeds metadata only)")
        return failures
    for name, floor in ACC_GATES:
        row = rows.get(name)
        if row is None:
            print(f"FAIL: accuracy row '{name}' missing")
            failures += 1
            continue
        try:
            mean, std, n = _seed_metric(row.get("derived") or "", "test_acc")
        except ValueError as e:
            print(f"FAIL: row '{name}': {e}")
            failures += 1
            continue
        lo = mean - 2.0 * std / n ** 0.5 if n > 1 else mean
        ok = lo >= floor
        verdict = "ok" if ok else "FAIL"
        print(f"{verdict}: {name} test_acc mean-2*stderr={lo:.3f} "
              f"(n_seeds={n}, want >= {floor:g})")
        if not ok:
            failures += 1
    return failures


def check(path: str) -> int:
    try:
        with open(path) as f:
            doc = json.load(f)
        rows = {r["name"]: r for r in doc["rows"]}
    except (OSError, KeyError, ValueError) as e:
        print(f"FAIL: cannot read bench rows from {path}: {e}")
        return 1
    fast = bool(doc.get("fast"))
    failures = 0
    for name, key, bound, direction in GATES:
        row = rows.get(name)
        if row is None:
            print(f"FAIL: row '{name}' missing from {path}")
            print("      (its benchmark did not run or the row was renamed)")
            failures += 1
            continue
        try:
            val = _metric(row.get("derived") or "", key)
        except ValueError as e:
            print(f"FAIL: row '{name}': {e}")
            failures += 1
            continue
        ok = val >= bound if direction == ">=" else val <= bound
        verdict = "ok" if ok else "FAIL"
        want = f"(want {direction} {bound:g}x)"
        print(f"{verdict}: {name} {key}={val:g}x {want}")
        if not ok:
            failures += 1
    failures += _check_stats(rows, fast)
    # bytes-moved budget: every aggregators/*_kernel row must stream no more
    # than its ideal once-through traffic (roofline.BYTES_TOL)
    try:
        from benchmarks.roofline import check_bytes, load_bench
    except ImportError:  # invoked as a path, not a module
        sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))
        from benchmarks.roofline import check_bytes, load_bench
    byte_fails = check_bytes(load_bench(path))
    for msg in byte_fails:
        print(f"FAIL: bytes-moved budget: {msg}")
    failures += len(byte_fails)
    if not byte_fails:
        print("ok: bytes-moved budget (aggregators/*_kernel rows)")
    if failures:
        print(f"{failures} perf gate(s) failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1] if len(sys.argv) > 1 else "BENCH_cpu.json"))
