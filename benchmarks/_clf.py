"""Shared benchmark utilities. The classification task itself lives in the
package (``repro.data.classification``) so examples run off a plain install;
this module re-exports it for the benchmark modules and keeps the
benchmark-only ``timed`` helper."""
from __future__ import annotations

import time

import jax

from repro.data.classification import (  # noqa: F401 (re-exports)
    DIM, HIDDEN, N_CLASSES, clf_logits, clf_loss, init_clf,
    make_index_sampler, make_task,
)


def seed_stat(label: str, vals, fmt: str = ".3f") -> str:
    """Derived-field fragment for a multi-seed metric: honest error bars.

    ``label=<mean>+-<std>;n_seeds=<n>`` with the *sample* std (ddof=1) when
    the sample has 2+ seeds; with a single seed there is no spread to report,
    so the ``+-`` is omitted entirely — a ``+-0.000`` from n=1 is typography,
    not statistics (the ISSUE-10 bugfix; jaxlint JXL006 flags regressions).
    ``n_seeds`` always rides along so ``check_regression.py`` can gate that
    full-mode accuracy rows carry real replication."""
    vals = [float(v) for v in vals]
    n = len(vals)
    mean = sum(vals) / n
    if n == 1:
        return f"{label}={mean:{fmt}};n_seeds=1"
    var = sum((v - mean) ** 2 for v in vals) / (n - 1)
    return f"{label}={mean:{fmt}}+-{var ** 0.5:{fmt}};n_seeds={n}"


def timed(fn, *args, warmup: int = 1, iters: int = 5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return out, (time.perf_counter() - t0) / iters * 1e6  # us
