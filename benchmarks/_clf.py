"""Shared benchmark utilities. The classification task itself lives in the
package (``repro.data.classification``) so examples run off a plain install;
this module re-exports it for the benchmark modules and keeps the
benchmark-only ``timed`` helper."""
from __future__ import annotations

import time

import jax

from repro.data.classification import (  # noqa: F401 (re-exports)
    DIM, HIDDEN, N_CLASSES, clf_logits, clf_loss, init_clf, make_task,
)


def timed(fn, *args, warmup: int = 1, iters: int = 5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return out, (time.perf_counter() - t0) / iters * 1e6  # us
