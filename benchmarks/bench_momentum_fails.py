"""Figures 3 & 4 (Appendix E): the momentum-tailored dynamic attack.

Quadratic f(x) = 0.5 xᵀAx, m=3 workers, one Byzantine at a time rotating per
the App. E schedule. Worker-momentum (β ∈ {0.9, 0.99}) stalls at a level that
grows with the attack strength λ; DynaBRO keeps converging.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._clf import seed_stat
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig, run_dynabro, run_momentum
from repro.core.switching import get_switcher
from repro.optim.optimizers import sgd

A = jnp.array([[2.0, 1.0], [1.0, 2.0]])
SIGMA = 0.5
P0 = {"x": jnp.array([3.0, -2.0])}


def grad_fn(params, unit_key):
    return {"x": A @ params["x"] + SIGMA * jax.random.normal(unit_key, (2,))}


def sampler(m, seed=0):
    def sample(t, n):
        keys = jax.random.split(jax.random.fold_in(jax.random.PRNGKey(seed), t), m * n)
        return keys.reshape(m, n, *keys.shape[1:])
    return sample


def f_val(p):
    return float(0.5 * p["x"] @ A @ p["x"])


def run(T: int = 1500, seeds=(0, 1, 2)):
    rows = []
    m = 3
    for lam in (0.0, 1.0, 2.0, 5.0):
        for beta in (0.9, 0.99):
            for mode in ("static", "dynamic"):
                finals = []
                for s in seeds:
                    alpha = 1.0 - beta
                    sw = (get_switcher("momentum_tailored", m, alpha=alpha)
                          if mode == "dynamic" else
                          get_switcher("static", m, n_byz=1, seed=s))
                    cfg = DynaBROConfig(
                        mlmc=MLMCConfig(T=T, m=m, V=4 * SIGMA, option=1, kappa=1.0),
                        aggregator="cwmed", attack="shift",
                        attack_kwargs={"v": lam})
                    p, _ = run_momentum(grad_fn, P0, cfg, sw, sampler(m, s), T,
                                        lr=5e-3, beta=beta, seed=s)
                    finals.append(f_val(p))
                rows.append((f"momentum_b{beta}_{mode}_lam{lam}", finals))
        # DynaBRO under the dynamic attack (α of the strongest momentum)
        finals = []
        for s in seeds:
            sw = get_switcher("momentum_tailored", m, alpha=0.01)
            cfg = DynaBROConfig(
                mlmc=MLMCConfig(T=T, m=m, V=4 * SIGMA, option=1, kappa=1.0),
                aggregator="cwmed", attack="shift", attack_kwargs={"v": lam})
            p, _, _ = run_dynabro(grad_fn, P0, sgd(5e-3), cfg, sw,
                                  sampler(m, s), T, seed=s)
            finals.append(f_val(p))
        rows.append((f"dynabro_dynamic_lam{lam}", finals))
    return rows


def main(fast: bool = False):
    rows = run(T=300 if fast else 1500, seeds=(0,) if fast else (0, 1, 2))
    return [f"momentum_fails/{name},,{seed_stat('final_gap', finals, '.4f')}"
            for name, finals in rows]


if __name__ == "__main__":
    print("\n".join(main()))
