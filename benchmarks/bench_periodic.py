"""Figure 1 / Figure 5: Periodic(K) identity switching on classification.

m=17 workers, δm=8 Byzantine, SF attack, CWTM aggregation (the paper's MNIST
configuration) on the synthetic classification task. Final test accuracy for
K ∈ {5, 20, 100, ∞}: DynaBRO stays stable across K; worker-momentum degrades
once K < 1/(1-β) (its effective averaging window).

Seeds are replicate lanes of ONE vmapped sweep dispatch (DESIGN.md §12): the
task (dataset + init) is fixed at the base seed, while each replicate folds
its own switcher schedule, attack key stream and batch-index stream — so the
error bars measure run-to-run stochasticity of the *algorithm*, and a 2-seed
full run costs the same dispatches as fast mode. The momentum baselines have
no sweep driver and loop per seed with the same per-seed stream convention.
"""
from __future__ import annotations

from benchmarks._clf import make_index_sampler, make_task, seed_stat
from repro.api.session import Session
from repro.api.specs import SweepSpec
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig, run_momentum
from repro.core.switching import get_switcher
from repro.optim.optimizers import sgd

M, NBYZ = 17, 8


def run(T: int = 400, Ks=(5, 20, 100, 10_000_000), seeds=(0, 1)):
    base = seeds[0]
    params0, grad_fn, sampler, eval_fn = make_task(M, seed=base)
    cfg = DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=M, V=5.0, option=1, kappa=1.0, j_cap=5),
        aggregator="cwtm", delta=NBYZ / M + 1e-3, attack="sign_flip")
    sess = Session(cfg, grad_fn=grad_fn, params0=params0, opt=sgd(0.1), m=M,
                   sample_batches=sampler, seed=base,
                   sampler_factory=lambda s: make_index_sampler(M, seed=s))
    spec = SweepSpec(
        switchers=tuple(("periodic", dict(n_byz=NBYZ, K=K)) for K in Ks),
        seeds=tuple(seeds))
    outs = sess.sweep(spec, T)
    cells = outs if len(seeds) > 1 else [[cell] for cell in outs]
    # jaxlint: disable=JXL003 -- 2.5 = 5/2 is exact in binary, so T*2.5 is exact; intended grad-budget truncation
    Tm = int(T * 2.5)  # equal grad budget: MLMC uses ~2.5 grads/round
    rows = []
    for K, cell in zip(Ks, cells):
        kname = "inf" if K >= 10_000_000 else str(K)
        accs = {"dynabro": [eval_fn(p, T)["test_acc"] for p, _ in cell],
                "momentum0.9": [], "momentum0.99": [], "sgd": []}
        for s in seeds:
            sampler_s = make_index_sampler(M, seed=s)
            for beta in (0.9, 0.99, 0.0):
                sw = get_switcher("periodic", M, n_byz=NBYZ, K=K, seed=s)
                pm, _ = run_momentum(grad_fn, params0, cfg, sw, sampler_s,
                                     Tm, lr=0.05, beta=beta, seed=s)
                tag = "sgd" if beta == 0.0 else f"momentum{beta}"
                accs[tag].append(eval_fn(pm, Tm)["test_acc"])
        for meth, vals in accs.items():
            rows.append((f"K={kname}/{meth}", vals))
    return rows


def main(fast: bool = False):
    rows = run(T=120 if fast else 400,
               Ks=(5, 10_000_000) if fast else (5, 20, 100, 10_000_000),
               seeds=(0,) if fast else (0, 1))
    return [f"periodic_sf_cwtm/{n},,{seed_stat('test_acc', vals)}"
            for n, vals in rows]


if __name__ == "__main__":
    print("\n".join(main()))
