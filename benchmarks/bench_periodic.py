"""Figure 1 / Figure 5: Periodic(K) identity switching on classification.

m=17 workers, δm=8 Byzantine, SF attack, CWTM aggregation (the paper's MNIST
configuration) on the synthetic classification task. Final test accuracy for
K ∈ {5, 20, 100, ∞}: DynaBRO stays stable across K; worker-momentum degrades
once K < 1/(1-β) (its effective averaging window).
"""
from __future__ import annotations

import numpy as np

from benchmarks._clf import make_task
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig, run_dynabro, run_momentum
from repro.core.switching import get_switcher
from repro.optim.optimizers import sgd

M, NBYZ = 17, 8


def run(T: int = 400, Ks=(5, 20, 100, 10_000_000), seeds=(0, 1)):
    rows = []
    for K in Ks:
        kname = "inf" if K >= 10_000_000 else str(K)
        accs = {"dynabro": [], "momentum0.9": [], "momentum0.99": [], "sgd": []}
        for s in seeds:
            params0, grad_fn, sampler, eval_fn = make_task(M, seed=s)
            cfg = DynaBROConfig(
                mlmc=MLMCConfig(T=T, m=M, V=5.0, option=1, kappa=1.0, j_cap=5),
                aggregator="cwtm", delta=NBYZ / M + 1e-3, attack="sign_flip")
            sw = get_switcher("periodic", M, n_byz=NBYZ, K=K, seed=s)
            p, _, _ = run_dynabro(grad_fn, params0, sgd(0.1), cfg, sw, sampler,
                                  T, seed=s)
            accs["dynabro"].append(eval_fn(p, T)["test_acc"])
            # equal total gradient budget: MLMC uses ~2.5 grads/round in expectation
            # jaxlint: disable=JXL003 -- 2.5 = 5/2 is exact in binary, so T*2.5 is exact; intended grad-budget truncation
            Tm = int(T * 2.5)
            for beta in (0.9, 0.99, 0.0):
                sw2 = get_switcher("periodic", M, n_byz=NBYZ, K=K, seed=s)
                pm, _ = run_momentum(grad_fn, params0, cfg, sw2, sampler, Tm,
                                     lr=0.05, beta=beta, seed=s)
                tag = "sgd" if beta == 0.0 else f"momentum{beta}"
                accs[tag].append(eval_fn(pm, Tm)["test_acc"])
        for meth, vals in accs.items():
            rows.append((f"K={kname}/{meth}", float(np.mean(vals)), float(np.std(vals))))
    return rows


def main(fast: bool = False):
    rows = run(T=120 if fast else 400,
               Ks=(5, 10_000_000) if fast else (5, 20, 100, 10_000_000),
               seeds=(0,) if fast else (0, 1))
    return [f"periodic_sf_cwtm/{n},,test_acc={m:.3f}+-{s:.3f}" for n, m, s in rows]


if __name__ == "__main__":
    print("\n".join(main()))
