"""§Roofline report: read experiments/dryrun/*.json, derive the three roofline
terms per (arch × shape × mesh), identify the dominant bottleneck, and emit
the markdown tables for EXPERIMENTS.md.

    compute term    = HLO_FLOPs / (chips × 197e12 FLOP/s)
    memory term     = HLO_bytes / (chips × 819e9 B/s)
    collective term = collective_bytes_per_device / 50e9 B/s  (per-link)

cost_analysis() on the SPMD-partitioned module reports per-device FLOPs/bytes
already, so no division by chip count is applied to those; collective bytes
are parsed per device from the HLO (ring (n-1)/n conventions, scan
trip-weighted).

  PYTHONPATH=src python -m benchmarks.roofline [--write-md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12  # TPU v5e bf16
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def model_flops(rec) -> float:
    """6·N_active·D tokens processed per step (training) or per token
    (decode); prefill uses 2·N_active·D (forward only)."""
    n = rec["active_params"]
    if rec["kind"] == "train":
        toks = {"train_4k": 256 * 4096}.get(rec["shape"], 0)
        return 6.0 * n * toks
    if rec["kind"] == "prefill":
        toks = {"prefill_32k": 32 * 32768}.get(rec["shape"], 0)
        return 2.0 * n * toks
    toks = {"decode_32k": 128, "long_500k": 1}.get(rec["shape"], 1)
    return 2.0 * n * toks


def load(mesh_filter=None):
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if mesh_filter and r.get("mesh") != mesh_filter:
            continue
        recs.append(r)
    return recs


def analyze(rec):
    if rec.get("skipped"):
        return None
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    flops = rec.get("flops") or 0.0
    byts = rec.get("bytes_accessed") or 0.0
    coll = rec["collectives"]["total_bytes"]
    # cost_analysis is per-device on the partitioned module
    t_comp = flops / PEAK_FLOPS
    t_mem = byts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec) / chips
    useful = mf / flops if flops else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops_per_chip": mf, "useful_flops_frac": useful,
        "hbm_temp_gb": (rec["memory"].get("temp_size_in_bytes") or 0) / 2**30,
        "hbm_args_gb": (rec["memory"].get("argument_size_in_bytes") or 0) / 2**30,
        "coll_bytes_gb": coll / 2**30,
    }


def fmt_s(x):
    if x == 0:
        return "0"
    for unit, f in (("s", 1), ("ms", 1e3), ("us", 1e6)):
        if x * f >= 1:
            return f"{x*f:.2f}{unit}"
    return f"{x*1e6:.3f}us"


def table(recs, mesh):
    rows = [analyze(r) for r in recs if r.get("mesh") == mesh or r.get("skipped")]
    out = ["| arch | shape | compute | memory | collective | dominant | useful-FLOPs | temp HBM | args HBM |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r is None:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute_s'])} | "
            f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_flops_frac']*100:.0f}% | "
            f"{r['hbm_temp_gb']:.1f}GB | {r['hbm_args_gb']:.1f}GB |")
    skipped = [r for r in recs if r.get("skipped")]
    for r in skipped:
        out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped: "
                   f"{r['reason']} | | | |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    recs = load()
    if args.csv:
        print("name,us_per_call,derived")
        for r in recs:
            a = analyze(r)
            if not a:
                continue
            dom_t = max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"])
            print(f"roofline/{a['arch']}/{a['shape']}/{a['mesh']},"
                  f"{dom_t*1e6:.0f},dominant={a['dominant']};useful="
                  f"{a['useful_flops_frac']*100:.0f}%")
        return
    print(table(recs, args.mesh))


if __name__ == "__main__":
    main()
