"""§Roofline report: read experiments/dryrun/*.json, derive the three roofline
terms per (arch × shape × mesh), identify the dominant bottleneck, and emit
the markdown tables for EXPERIMENTS.md.

    compute term    = HLO_FLOPs / (chips × 197e12 FLOP/s)
    memory term     = HLO_bytes / (chips × 819e9 B/s)
    collective term = collective_bytes_per_device / 50e9 B/s  (per-link)

cost_analysis() on the SPMD-partitioned module reports per-device FLOPs/bytes
already, so no division by chip count is applied to those; collective bytes
are parsed per device from the HLO (ring (n-1)/n conventions, scan
trip-weighted).

Also ingests the ``aggregators/*`` rows of a BENCH json (``--bench``): those
rows carry a per-call bytes-moved model — ``MB_in``/``MB_out`` are the ideal
once-through traffic for the rule, ``MB_moved`` is what the implementation
actually streams (the fused one-pass kernel reads the gradient stack once;
split pipelines re-read it per stage, 2–3x). The report shows achieved vs
ideal bytes per rule plus the realized bandwidth, and ``--check`` fails (for
CI) if any ``*_kernel`` row moves more than BYTES_TOL times its ideal —
the budget that keeps the fused kernel honest about its one-pass claim.

  PYTHONPATH=src python -m benchmarks.roofline [--mesh M] [--csv]
  PYTHONPATH=src python -m benchmarks.roofline --bench BENCH_cpu.json [--check]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12  # TPU v5e bf16
HBM_BW = 819e9
ICI_BW = 50e9
# a *_kernel row may move at most this multiple of its ideal (MB_in+MB_out)
BYTES_TOL = 1.01

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def model_flops(rec) -> float:
    """6·N_active·D tokens processed per step (training) or per token
    (decode); prefill uses 2·N_active·D (forward only)."""
    n = rec["active_params"]
    if rec["kind"] == "train":
        toks = {"train_4k": 256 * 4096}.get(rec["shape"], 0)
        return 6.0 * n * toks
    if rec["kind"] == "prefill":
        toks = {"prefill_32k": 32 * 32768}.get(rec["shape"], 0)
        return 2.0 * n * toks
    toks = {"decode_32k": 128, "long_500k": 1}.get(rec["shape"], 1)
    return 2.0 * n * toks


def load(mesh_filter=None, bench=None):
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if mesh_filter and r.get("mesh") != mesh_filter:
            continue
        recs.append(r)
    if bench:
        recs.extend(load_bench(bench))
    return recs


def _parse_derived(derived):
    """'MB_in=4.19;impl=pallas;vs_ref=3.6x' -> dict (floats where they parse,
    trailing benchmark-convention 'x' stripped)."""
    fields = {}
    for part in (derived or "").split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            fields[k] = float(v.rstrip("x%"))
        except ValueError:
            fields[k] = v
    return fields


def load_bench(path):
    """aggregators/* rows of a BENCH json as roofline records
    (kind='agg_bench'); rows without a bytes model (MB_in) are skipped."""
    with open(path) as f:
        rows = json.load(f)["rows"]
    recs = []
    for row in rows:
        name = row["name"]
        if not name.startswith("aggregators/"):
            continue
        fields = _parse_derived(row.get("derived") or "")
        if "MB_in" not in fields:
            continue
        us = row.get("us_per_call")
        recs.append({"kind": "agg_bench", "rule": name.split("/", 1)[1],
                     "us_per_call": float(us) if us else 0.0, **fields})
    return recs


def _analyze_agg(rec):
    ideal = rec["MB_in"] + rec.get("MB_out", 0.0)
    moved = rec.get("MB_moved", ideal)
    us = rec["us_per_call"]
    return {
        "kind": "agg_bench", "rule": rec["rule"],
        "us_per_call": us,
        "mb_ideal": ideal, "mb_moved": moved,
        "bytes_ratio": moved / ideal if ideal else 1.0,
        "gb_per_s": moved / us * 1e6 / 1e3 if us else 0.0,
        "impl": rec.get("impl", "?"),
    }


def analyze(rec):
    if rec.get("skipped"):
        return None
    if rec.get("kind") == "agg_bench":
        return _analyze_agg(rec)
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    flops = rec.get("flops") or 0.0
    byts = rec.get("bytes_accessed") or 0.0
    coll = rec["collectives"]["total_bytes"]
    # cost_analysis is per-device on the partitioned module
    t_comp = flops / PEAK_FLOPS
    t_mem = byts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec) / chips
    useful = mf / flops if flops else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops_per_chip": mf, "useful_flops_frac": useful,
        "hbm_temp_gb": (rec["memory"].get("temp_size_in_bytes") or 0) / 2**30,
        "hbm_args_gb": (rec["memory"].get("argument_size_in_bytes") or 0) / 2**30,
        "coll_bytes_gb": coll / 2**30,
    }


def fmt_s(x):
    if x == 0:
        return "0"
    for unit, f in (("s", 1), ("ms", 1e3), ("us", 1e6)):
        if x * f >= 1:
            return f"{x*f:.2f}{unit}"
    return f"{x*1e6:.3f}us"


def table(recs, mesh):
    rows = [analyze(r) for r in recs if r.get("mesh") == mesh or r.get("skipped")]
    out = ["| arch | shape | compute | memory | collective | dominant | useful-FLOPs | temp HBM | args HBM |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r is None:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute_s'])} | "
            f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_flops_frac']*100:.0f}% | "
            f"{r['hbm_temp_gb']:.1f}GB | {r['hbm_args_gb']:.1f}GB |")
    skipped = [r for r in recs if r.get("skipped")]
    for r in skipped:
        out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped: "
                   f"{r['reason']} | | | |")
    return "\n".join(out)


def agg_table(recs):
    rows = [analyze(r) for r in recs if r.get("kind") == "agg_bench"]
    out = ["| rule | impl | us/call | ideal MB | moved MB | moved/ideal | GB/s |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['rule']} | {r['impl']} | {r['us_per_call']:.0f} | "
            f"{r['mb_ideal']:.2f} | {r['mb_moved']:.2f} | "
            f"{r['bytes_ratio']:.2f}x | {r['gb_per_s']:.2f} |")
    return "\n".join(out)


def check_bytes(recs, tol=BYTES_TOL):
    """Failure strings for *_kernel agg rows moving more than tol× ideal."""
    fails = []
    for r in recs:
        a = analyze(r)
        if not a or a.get("kind") != "agg_bench":
            continue
        if a["rule"].endswith("_kernel") and a["bytes_ratio"] > tol:
            fails.append(f"{a['rule']}: moves {a['mb_moved']:.2f}MB vs ideal "
                         f"{a['mb_ideal']:.2f}MB ({a['bytes_ratio']:.2f}x > "
                         f"{tol}x budget)")
    return fails


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--bench", default=None,
                    help="BENCH json whose aggregators/* rows carry the "
                         "bytes-moved model")
    ap.add_argument("--check", action="store_true",
                    help="fail if any *_kernel bench row exceeds the "
                         "bytes-moved budget")
    args = ap.parse_args()
    if args.bench:
        recs = load_bench(args.bench)
        print(agg_table(recs))
        if args.check:
            fails = check_bytes(recs)
            for f in fails:
                print(f"FAIL {f}")
            if fails:
                raise SystemExit(1)
            print(f"bytes-moved budget OK ({len(recs)} rows, "
                  f"tol {BYTES_TOL}x)")
        return
    recs = load()
    if args.csv:
        print("name,us_per_call,derived")
        for r in recs:
            a = analyze(r)
            if not a:
                continue
            dom_t = max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"])
            print(f"roofline/{a['arch']}/{a['shape']}/{a['mesh']},"
                  f"{dom_t*1e6:.0f},dominant={a['dominant']};useful="
                  f"{a['useful_flops_frac']*100:.0f}%")
        return
    print(table(recs, args.mesh))


if __name__ == "__main__":
    main()
