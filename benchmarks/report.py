"""Generate the §Dry-run / §Roofline markdown tables for EXPERIMENTS.md from
the dry-run JSON artifacts.

  PYTHONPATH=src python -m benchmarks.report [--dir experiments/dryrun] [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12  # TPU v5e bf16 per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

ROOT = os.path.join(os.path.dirname(__file__), "..")

SHAPE_TOKENS = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
                "decode_32k": 128, "long_500k": 1}


def load(dirname):
    recs = {}
    for p in sorted(glob.glob(os.path.join(ROOT, dirname, "*.json"))):
        name = os.path.basename(p)[:-5]
        if "__probe" in name or "__opt" in name:
            continue
        with open(p) as f:
            recs[name] = json.load(f)
    return recs


def fmt_s(x):
    if x is None:
        return "—"
    if x == 0:
        return "0"
    for unit, f in (("s", 1), ("ms", 1e3), ("us", 1e6)):
        if x * f >= 1:
            return f"{x*f:.2f}{unit}"
    return f"{x*1e6:.3f}us"


def fmt_b(x):
    if not x:
        return "0"
    for unit, f in (("TB", 2**40), ("GB", 2**30), ("MB", 2**20)):
        if x >= f:
            return f"{x/f:.1f}{unit}"
    return f"{x:.0f}B"


def terms(rec):
    if rec.get("skipped"):
        return None
    w = rec.get("weighted") or {}
    flops = w.get("flops_weighted") or rec.get("flops") or 0.0
    byts = w.get("bytes_weighted") or rec.get("bytes_accessed") or 0.0
    coll = rec["collectives"]["total_bytes"]
    t = {"compute": flops / PEAK_FLOPS, "memory": byts / HBM_BW,
         "collective": coll / ICI_BW}
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    mult = {"train": 3.0, "prefill": 1.0, "decode": 1.0}[rec["kind"]]
    mf = mult * 2.0 * rec["active_params"] * SHAPE_TOKENS[rec["shape"]] / chips
    return t, max(t, key=t.get), mf, flops


def dryrun_table(recs, mesh):
    rows = ["| arch | shape | compiled | t_compile | HBM temp | HBM args | collectives (count) | collective bytes/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for name, r in sorted(recs.items()):
        if r.get("skipped"):
            if mesh in name:
                rows.append(f"| {r['arch']} | {r['shape']} | SKIP ({r['reason'][:40]}…) | | | | | |")
            continue
        if r["mesh"] != mesh:
            continue
        c = r["collectives"]
        cnt = ", ".join(f"{k.split('-')[-1][:3]}:{int(v)}" for k, v in
                        c["counts"].items() if v)
        rows.append(
            f"| {r['arch']} | {r['shape']} | ✓ | {r['t_compile_s']}s | "
            f"{fmt_b(r['memory'].get('temp_size_in_bytes'))} | "
            f"{fmt_b(r['memory'].get('argument_size_in_bytes'))} | "
            f"{cnt} | {fmt_b(c['total_bytes'])} |")
    return "\n".join(rows)


def roofline_table(recs, mesh):
    rows = ["| arch | shape | compute | memory | collective | dominant | 6ND/HLO | note |",
            "|---|---|---|---|---|---|---|---|"]
    notes = {
        ("collective",): "drive down the dominant collective (see §Perf)",
    }
    for name, r in sorted(recs.items()):
        if r.get("skipped") or r["mesh"] != mesh:
            continue
        t, dom, mf, flops = terms(r)
        ratio = f"{mf/flops*100:.0f}%" if flops else "—"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute'])} | "
            f"{fmt_s(t['memory'])} | {fmt_s(t['collective'])} | **{dom}** | "
            f"{ratio} | |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--kind", default="roofline", choices=["roofline", "dryrun"])
    args = ap.parse_args()
    recs = load(args.dir)
    if args.kind == "dryrun":
        print(dryrun_table(recs, args.mesh))
    else:
        print(roofline_table(recs, args.mesh))


if __name__ == "__main__":
    main()
