"""``repro.api`` — the one facade over the reproduction (DESIGN.md §10).

Quickstart::

    from repro.api import (MLMCConfig, DynaBROConfig, build_session,
                           make_quadratic_task, get_switcher, sgd)

    task = make_quadratic_task()
    cfg = DynaBROConfig(mlmc=MLMCConfig(T=200, m=16, V=3.0))
    sess = build_session(cfg, task, m=16, opt=sgd(2e-2),
                         switcher=get_switcher("periodic", 16, n_byz=3, K=10))
    params, logs, evals = sess.run(200)        # compiled batch driver
    carry = sess.init_carry()                  # ... or round by round:
    sched = sess.schedule(200)
    carry, info = sess.step(carry, sess.round_inputs(sched, 0))

Everything here re-exports from the implementation modules; the historical
``run_*`` entrypoints are thin wrappers over ``Session`` and remain
importable from their original homes.
"""
from repro.api.session import (
    RoundInputs, RoundSchedule, Session, StepInfo, build_session,
)
from repro.api.specs import AggSpec, AttackSpec, SweepSpec
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import (
    DynaBROConfig, RoundLog, make_dynabro_scan_fn, make_momentum_scan_fn,
    run_dynabro, run_dynabro_scan, run_dynabro_scan_sweep, run_momentum,
    run_momentum_scan,
)
from repro.core.scenarios import (
    Scenario, Task, format_table, make_quadratic_task, run_matrix,
    run_scenario, scenario_grid,
)
from repro.core.switching import Switcher, get_switcher
from repro.launch.mesh import make_lane_mesh, make_worker_mesh
from repro.optim.optimizers import Optimizer, adagrad_norm, adam, momentum, sgd

__all__ = [
    "AggSpec", "AttackSpec", "SweepSpec",
    "RoundInputs", "RoundSchedule", "Session", "StepInfo", "build_session",
    "MLMCConfig", "DynaBROConfig", "RoundLog",
    "make_dynabro_scan_fn", "make_momentum_scan_fn",
    "run_dynabro", "run_dynabro_scan", "run_dynabro_scan_sweep",
    "run_momentum", "run_momentum_scan",
    "Scenario", "Task", "format_table", "make_quadratic_task", "run_matrix",
    "run_scenario", "scenario_grid",
    "Switcher", "get_switcher",
    "make_lane_mesh", "make_worker_mesh",
    "Optimizer", "adagrad_norm", "adam", "momentum", "sgd",
]
