"""Validated scenario/sweep specs — the config side of the ``repro.api``
facade (DESIGN.md §10).

The sweep machinery historically grew three parallel encodings of "which
attack / rule does this lane run": per-cell config fields
(``DynaBROConfig.aggregator`` + ``delta`` + ``aggregator_kwargs``), per-lane
traced vectors (``agg_theta`` + ``thr_coeff``), and the prebuilt-scan_fn
forms (``lane_attacks``/``lane_aggregators`` tuples, ``scan_fn`` either a
function or a ``{rule: scan_fn}`` mapping). ``AttackSpec`` / ``AggSpec`` /
``SweepSpec`` collapse that sprawl into one validated source of truth: a
spec validates its rule name and hyperparameters at construction (with
errors that name the valid choices) and can emit *every* downstream form —
``AggSpec.theta()`` for the lane path, ``AggSpec.apply_to(cfg)`` for the
per-cell path, ``SweepSpec.scan_fn`` for the steady-state prebuilt form —
so the encodings cannot drift.

The raw kwarg forms on ``run_dynabro_scan_sweep`` remain as a thin
compatibility layer for one release (everything is coerced through this
module, so they gain the same validation); the ``{rule: scan_fn}`` mapping
kwarg emits a ``DeprecationWarning`` pointing here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Tuple, Union

from repro.core import agg_engine
from repro.core import attacks as attacks_lib
from repro.core.mlmc import MLMCConfig
from repro.core.switching import Switcher, get_switcher


def _freeze_kwargs(kw: Optional[Mapping[str, Any]]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted((dict(kw or {})).items()))


@dataclasses.dataclass(frozen=True)
class AttackSpec:
    """One validated attack choice: name + parameter overrides.

    Construction validates eagerly: an unknown attack or parameter raises
    with the valid choices named, instead of failing deep inside a traced
    sweep. ``theta()`` is the per-lane traced vector
    (``attacks.attack_theta``); ``legacy`` the ``(name, kwargs)`` tuple the
    pre-spec call sites pass around.
    """

    name: str
    params: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self):
        if self.name not in attacks_lib.ATTACKS:
            raise ValueError(
                f"unknown attack {self.name!r}; known: "
                f"{tuple(sorted(attacks_lib.ATTACKS))}")
        object.__setattr__(self, "params", _freeze_kwargs(dict(self.params)))
        self.theta()  # validates parameter names/values (raises on unknown)

    @classmethod
    def make(cls, name: str, **params) -> "AttackSpec":
        return cls(name, _freeze_kwargs(params))

    @classmethod
    def coerce(cls, spec: "AttackLike") -> "AttackSpec":
        """Accept a name, a ``(name, kwargs)`` pair, or an AttackSpec."""
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            return cls(spec)
        try:
            name, kw = spec
        except (TypeError, ValueError):
            raise ValueError(
                f"cannot interpret {spec!r} as an attack spec; pass a name, "
                f"a (name, kwargs) pair, or an AttackSpec") from None
        return cls(name, _freeze_kwargs(kw))

    @property
    def kwargs(self) -> Dict[str, Any]:
        return dict(self.params)

    @property
    def legacy(self) -> Union[str, Tuple[str, Dict[str, Any]]]:
        return (self.name, self.kwargs) if self.params else self.name

    def theta(self):
        """(N_PARAMS,) traced parameter row — the lane-path encoding."""
        return attacks_lib.attack_theta(self.name, self.kwargs)

    @property
    def label(self) -> str:
        kw = ",".join(f"{k}={v}" for k, v in self.params)
        return f"{self.name}({kw})" if kw else self.name


@dataclasses.dataclass(frozen=True)
class AggSpec:
    """One validated aggregation-rule choice: rule + hyperparameters.

    The single source both rule encodings derive from:

    - per-lane (traced) form: ``theta()`` (= ``agg_engine.agg_theta``) and
      ``thr_coeff(mlmc)`` — the lane's fail-safe coefficient, Option-2
      (δ-oblivious) for MFM and Option-1 for every other rule, exactly as
      ``scenarios._cell_cfg`` configures cells;
    - per-cell (config) form: ``apply_to(cfg)`` returns the cfg a per-cell
      ``run_dynabro_scan`` reference run must use for this rule — the
      ``aggregator`` / ``delta`` / ``aggregator_kwargs`` / MLMC-option
      fields set consistently with the lane encoding above.
    """

    rule: str
    params: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self):
        agg_engine.agg_param_spec(self.rule)  # unknown rule -> ValueError
        object.__setattr__(self, "params", _freeze_kwargs(dict(self.params)))
        self.theta()  # validates hyperparameter names/values

    @classmethod
    def make(cls, rule: str, **params) -> "AggSpec":
        return cls(rule, _freeze_kwargs(params))

    @classmethod
    def coerce(cls, spec: "AggLike") -> "AggSpec":
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            return cls(spec)
        try:
            rule, kw = spec
        except (TypeError, ValueError):
            raise ValueError(
                f"cannot interpret {spec!r} as an aggregator spec; pass a "
                f"rule name, a (rule, kwargs) pair, or an AggSpec") from None
        return cls(rule, _freeze_kwargs(kw))

    @property
    def kwargs(self) -> Dict[str, Any]:
        return dict(self.params)

    @property
    def legacy(self) -> Union[str, Tuple[str, Dict[str, Any]]]:
        return (self.rule, self.kwargs) if self.params else self.rule

    def theta(self):
        """(N_AGG_PARAMS,) traced hyperparameter row — the lane encoding."""
        return agg_engine.agg_theta(self.rule, self.kwargs)

    def thr_coeff(self, mlmc: MLMCConfig) -> float:
        """The lane's fail-safe coefficient (1+√2)·c_E·C·V: MFM lanes run
        the paper's δ-oblivious Option 2, every other rule Option 1."""
        option = 2 if self.rule == "mfm" else 1
        return float(dataclasses.replace(mlmc, option=option).threshold_coeff)

    def apply_to(self, cfg) -> Any:
        """The per-cell ``DynaBROConfig`` equivalent of this lane — what a
        per-cell reference run of the same rule must be configured with."""
        kw = self.kwargs
        return dataclasses.replace(
            cfg,
            mlmc=dataclasses.replace(
                cfg.mlmc, option=2 if self.rule == "mfm" else 1),
            aggregator=self.rule,
            delta=kw.get("delta", cfg.delta),
            aggregator_kwargs=kw or None)

    @property
    def label(self) -> str:
        kw = ",".join(f"{k}={v}" for k, v in self.params)
        return f"{self.rule}({kw})" if kw else self.rule


AttackLike = Union[str, Tuple[str, Mapping[str, Any]], AttackSpec]
AggLike = Union[str, Tuple[str, Mapping[str, Any]], AggSpec]
SwitcherLike = Union[str, Tuple[str, Mapping[str, Any]], Switcher]


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One validated description of a lane-batched sweep (DESIGN.md §7/§10).

    ``switchers`` is the lane axis (one entry per lane: a ``Switcher``
    instance, a name, or ``(name, kwargs)`` resolved against the session's
    ``m``/``seed``); ``attacks`` / ``aggregators`` optionally give each lane
    its own attack / rule (AttackSpec / AggSpec or their legacy encodings —
    everything is coerced and validated here, with lane-count mismatches
    reported up front). ``scan_fn`` carries the steady-state prebuilt form:
    either one lane-built scan_fn for a branch-homogeneous grid, or a
    ``{rule_name: scan_fn}`` mapping with one single-rule scan_fn per
    distinct rule of a mixed grid.

    ``seeds`` / ``replicates`` add the **replicate axis** (DESIGN.md §12):
    every cell is run once per replicate seed, each replicate folding its
    own data-sampler, switcher-mask and attack-key streams while the MLMC
    level plan stays a function of the *session* seed alone — replicates
    are paired on levels across cells, so cross-cell comparisons stay
    low-variance and the ``lax.switch`` level index stays scalar. Pass
    explicit ``seeds=(s0, s1, ...)`` or a count ``replicates=N`` (seeds
    then default to ``session.seed + r``). With more than one replicate the
    switchers must be name / ``(name, kwargs)`` specs — a prebuilt
    ``Switcher`` instance carries one fixed seed and cannot be re-seeded
    per replicate.
    """

    switchers: Tuple[SwitcherLike, ...]
    attacks: Optional[Tuple[AttackSpec, ...]] = None
    aggregators: Optional[Tuple[AggSpec, ...]] = None
    scan_fn: Any = None
    seeds: Optional[Tuple[int, ...]] = None
    replicates: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "switchers", tuple(self.switchers))
        if self.seeds is not None:
            seeds = tuple(int(s) for s in self.seeds)
            if not seeds:
                raise ValueError("seeds= must name at least one seed")
            if len(set(seeds)) != len(seeds):
                raise ValueError(f"seeds= has duplicates: {seeds}")
            if self.replicates is not None \
                    and int(self.replicates) != len(seeds):
                raise ValueError(
                    f"replicates={self.replicates} disagrees with "
                    f"len(seeds)={len(seeds)}; pass one or the other")
            object.__setattr__(self, "seeds", seeds)
            object.__setattr__(self, "replicates", len(seeds))
        elif self.replicates is not None:
            if int(self.replicates) < 1:
                raise ValueError(
                    f"replicates= must be >= 1, got {self.replicates}")
            object.__setattr__(self, "replicates", int(self.replicates))
        C = len(self.switchers)
        for axis_name, specs, coerce in (
                ("attacks", self.attacks, AttackSpec.coerce),
                ("aggregators", self.aggregators, AggSpec.coerce)):
            if specs is None:
                continue
            specs = tuple(specs)
            # lane-count check first (the legacy drivers' error), THEN
            # per-spec validation — a wrong-length axis should say so even
            # when its entries are also malformed
            if len(specs) != C:
                raise ValueError(
                    f"{axis_name}: expected one per-lane spec per switcher "
                    f"({C}), got {len(specs)}")
            object.__setattr__(self, axis_name,
                               tuple(coerce(s) for s in specs))

    @property
    def lanes(self) -> int:
        return len(self.switchers)

    @property
    def n_replicates(self) -> int:
        return self.replicates if self.replicates is not None else 1

    def replicate_seeds(self, base_seed: int) -> Tuple[int, ...]:
        """The per-replicate seed tuple: explicit ``seeds=``, else
        ``base_seed + r`` for ``replicates=N`` (r = 0 is the base run)."""
        if self.seeds is not None:
            return self.seeds
        return tuple(base_seed + r for r in range(self.n_replicates))

    def resolve_switchers(self, m: Optional[int], seed: int):
        """Lane ``Switcher`` instances; name/(name, kwargs) entries need the
        session's worker count ``m`` (instances pass through untouched).
        With more than one replicate every entry must be a re-seedable
        name/(name, kwargs) spec — the sweep resolves the lane once per
        replicate seed (DESIGN.md §12)."""
        out = []
        for sw in self.switchers:
            if isinstance(sw, Switcher):
                if self.n_replicates > 1 or self.seeds is not None:
                    raise ValueError(
                        f"switcher instance {type(sw).__name__}(m={sw.m}, "
                        f"seed={sw.seed}) cannot be re-seeded per replicate; "
                        f"pass a name or (name, kwargs) spec when the sweep "
                        f"carries seeds=/replicates=")
                out.append(sw)
                continue
            name, kw = (sw, {}) if isinstance(sw, str) else (sw[0], dict(sw[1]))
            if m is None:
                raise ValueError(
                    f"switcher spec {sw!r} needs a worker count to resolve; "
                    f"build the session with m= (or pass Switcher instances)")
            out.append(get_switcher(name, m, seed=seed, **kw))
        return out

    def attack_lanes(self):
        """Per-lane ``(name, kwargs)`` pairs (the lane-plan input), or None."""
        if self.attacks is None:
            return None
        return [(a.name, a.kwargs) for a in self.attacks]

    def agg_lanes(self):
        if self.aggregators is None:
            return None
        return [(g.rule, g.kwargs) for g in self.aggregators]

    def lane_subset(self, idx, scan_fn=None) -> "SweepSpec":
        """The sub-spec of lanes ``idx`` — the branch-homogeneous grouping
        recursion's unit of work."""
        return SweepSpec(
            switchers=tuple(self.switchers[c] for c in idx),
            attacks=(None if self.attacks is None
                     else tuple(self.attacks[c] for c in idx)),
            aggregators=(None if self.aggregators is None
                         else tuple(self.aggregators[c] for c in idx)),
            scan_fn=scan_fn,
            seeds=self.seeds,
            replicates=self.replicates)
