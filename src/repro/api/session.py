"""The session driver API (DESIGN.md §10): one entrypoint under every driver.

A ``Session`` binds what all the historical ``run_*`` drivers took as
positional sprawl — grad_fn, initial params, optimizer, config, switcher,
batch sampler, seed, sharding options — and exposes the round loop at every
granularity:

- ``init_carry()`` / ``step(carry, round_inputs)``: ONE round at a time
  through the same jitted compiled segment the batch drivers scan with.
  Segment chunking is bitwise-invariant (locked by tests/test_checkpoint.py
  and the chunk parity tests), so driving length-1 segments is
  bitwise-identical to a whole-``T`` ``run()`` — this is what lets the
  aggregation server (``repro.serve``) consume rounds at network cadence and
  still match the offline driver bit for bit.
- ``run(T)``: the batch drivers (compiled scan or the legacy per-round jit
  reference), exactly as ``run_dynabro`` / ``run_dynabro_scan`` /
  ``run_momentum`` / ``run_momentum_scan`` always behaved — those functions
  are now thin wrappers over a Session (exact-parity locked by the existing
  driver parity suite).
- ``sweep(spec, T)``: the lane-batched vmapped sweep over a validated
  ``SweepSpec`` (``run_dynabro_scan_sweep`` wraps this).

All compiled-loop machinery (``make_*_scan_fn``, schedule precomputes, lane
plans, the vmapped-wrapper cache) stays in ``core.robust_train`` — the
Session is the *driver*, not the kernel — and is always called through the
module (``rt.``) so tests and tools that monkeypatch those attributes keep
working.
"""
from __future__ import annotations

import contextlib
import dataclasses
import inspect
import os
from typing import Any, Callable, Dict, List, Mapping, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.specs import SweepSpec
from repro.core import robust_train as rt
from repro.core.mlmc import round_cost, sample_level
from repro.core.switching import Switcher
from repro.lint import runtime as sanitizers
from repro.optim.optimizers import Optimizer

GUARD_ENV = "REPRO_RECOMPILE_GUARD"


@dataclasses.dataclass(frozen=True)
class RoundSchedule:
    """The host-precomputed round schedule for ``T`` rounds — the same
    levels/masks/keys the compiled drivers scan over (DESIGN.md §5), exposed
    so per-round callers (the serve loop, replay tests) can draw from the
    identical stream. Momentum-mode schedules have ``n_max == 1`` and masks
    of shape (T, m); DynaBRO masks are (T, n_max, m) within-round masks."""

    T: int
    levels: np.ndarray  # (T,) MLMC level plan (zeros in momentum mode)
    ns: np.ndarray      # (T,) per-round unit counts
    n_max: int
    masks: np.ndarray   # (T, n_max, m) bool — or (T, m) in momentum mode
    keys: np.ndarray    # (T, 2) uint32 raw PRNG keys


@dataclasses.dataclass
class RoundInputs:
    """Everything one round consumes. ``batches`` is the n_max-padded
    per-worker batch tree (leading (m, n_max) axes; momentum mode: (m,) unit
    batches); ``masks`` the round's Byzantine-identity mask — mutable by
    design, the serve loop ORs straggler bits into it (a timed-out worker is
    just a dynamically-Byzantine one, DESIGN.md §10)."""

    t: int
    level: int
    batches: Any
    masks: Any  # (n_max, m) bool — or (m,) in momentum mode
    key: Any    # (2,) uint32


@dataclasses.dataclass
class StepInfo:
    """Per-round diagnostics from ``step``: the MLMC fail-safe verdict and
    correction norm (None in momentum mode, which has neither)."""

    failsafe_ok: Optional[bool] = None
    corr_norm: Optional[float] = None


class Session:
    """One bound training session; see the module docstring. Use
    ``build_session`` (or the ``run_*`` wrappers) rather than spelling out
    every field.

    ``mode`` is ``"dynabro"`` (Algorithm 2; needs ``opt``) or ``"momentum"``
    (the worker-momentum baseline; needs ``lr``/``beta``). Prebuilt
    ``scan_fn``s are validated against the session's mesh/microbatch/lane
    configuration up front, with the same errors the batch drivers raise.
    """

    def __init__(self, cfg, *, grad_fn, params0, opt: Optional[Optimizer] = None,
                 switcher: Optional[Switcher] = None,
                 sample_batches: Optional[Callable[[int, int], Any]] = None,
                 seed: int = 0, mode: str = "dynabro",
                 lr: Optional[float] = None, beta: Optional[float] = None,
                 scan_fn=None, vectorize_batches: bool = True,
                 mesh=None, worker_axis: str = "workers", param_specs=None,
                 microbatch: bool = False, m: Optional[int] = None,
                 guard_recompiles: Optional[bool] = None,
                 nan_tripwire: Optional[bool] = None,
                 sampler_factory: Optional[Callable[[int], Any]] = None):
        if mode not in ("dynabro", "momentum"):
            raise ValueError(
                f"unknown session mode {mode!r}; expected 'dynabro' or "
                f"'momentum'")
        if mode == "dynabro" and opt is None:
            raise ValueError("dynabro sessions need opt= (an Optimizer)")
        if mode == "momentum" and (lr is None or beta is None):
            raise ValueError("momentum sessions need lr= and beta=")
        self.cfg = cfg
        self.grad_fn = grad_fn
        self.params0 = params0
        self.opt = opt
        self.switcher = switcher
        self.sample_batches = sample_batches
        self.sampler_factory = sampler_factory
        self.seed = seed
        self.mode = mode
        self.lr, self.beta = lr, beta
        self.vectorize_batches = vectorize_batches
        self.mesh = mesh
        self.worker_axis = worker_axis
        self.param_specs = param_specs
        self.microbatch = microbatch
        self.m = m if m is not None else (switcher.m if switcher else None)
        # preflight validation, identical to the batch drivers' (and at the
        # same point: before any T<=0 early return a run() might take)
        if mesh is not None:
            if self.m is None:
                raise ValueError("mesh= needs a worker count: pass switcher= "
                                 "or m=")
            rt._check_worker_mesh(mesh, worker_axis, self.m,
                                  allow_model=(mode == "dynabro"))
        if scan_fn is not None:
            if mode == "dynabro":
                for lane_kind in ("lane_attacks", "lane_aggregators"):
                    if getattr(scan_fn, lane_kind, None) is not None:
                        raise ValueError(
                            f"scan_fn was built with {lane_kind}="
                            f"{getattr(scan_fn, lane_kind)!r}; that variant "
                            f"is for run_dynabro_scan_sweep(...), not "
                            f"run_dynabro_scan")
            rt._check_scan_fn_mesh(scan_fn, mesh)
            if mode == "dynabro":
                have_mb = getattr(scan_fn, "microbatch", microbatch)
                if have_mb != microbatch:
                    raise ValueError(
                        f"scan_fn was built with microbatch={have_mb}, but "
                        f"this run passes microbatch={microbatch}; rebuild "
                        f"the scan_fn to match (the two paths are not "
                        "bitwise-equivalent)")
        self._scan_fn = scan_fn
        self._schedules: Dict[int, RoundSchedule] = {}
        # runtime sanitizers (DESIGN.md §11): the recompile guard asserts a
        # compiled-segment signature seen once before never compiles again
        # (steady state — serve inherits this through step); the NaN tripwire
        # host-checks aggregator-facing outputs. Both default to their env
        # opt-ins (REPRO_RECOMPILE_GUARD / REPRO_NAN_TRIPWIRE).
        if guard_recompiles is None:
            guard_recompiles = os.environ.get(GUARD_ENV, "").lower() in (
                "1", "true", "on")
        self.guard_recompiles = guard_recompiles
        self.nan_tripwire = nan_tripwire
        self._steady_sigs: Set[Tuple] = set()

    # ------------------------------------------------------------ pieces

    @property
    def scan_fn(self):
        """The session's compiled segment fn, built on first use (via the
        ``rt`` module attribute, so monkeypatched builders are honored)."""
        if self._scan_fn is None:
            if self.mode == "dynabro":
                self._scan_fn = rt.make_dynabro_scan_fn(
                    self.grad_fn, self.cfg, self.opt, mesh=self.mesh,
                    worker_axis=self.worker_axis,
                    param_specs=self.param_specs, microbatch=self.microbatch)
            else:
                self._scan_fn = rt.make_momentum_scan_fn(
                    self.grad_fn, self.cfg, self.lr, self.beta,
                    mesh=self.mesh, worker_axis=self.worker_axis)
        return self._scan_fn

    def schedule(self, T: int) -> RoundSchedule:
        """The full host-side round schedule (cached per T) — exactly the
        precompute of the compiled batch drivers, so per-round stepping and
        ``run(T)`` draw from one stream."""
        sched = self._schedules.get(T)
        if sched is not None:
            return sched
        if self.switcher is None:
            raise ValueError("schedules need a switcher; build the session "
                             "with switcher=")
        if self.mode == "dynabro":
            levels, ns, n_max = rt._level_plan(
                self.cfg, np.random.default_rng(self.seed), T)
            masks = rt._mask_schedule(self.switcher, T, n_max, ns)
            keys = rt._np_prng_keys(
                self.seed * 100_003 + np.arange(T, dtype=np.int64))
        else:
            levels = np.zeros(T, np.int32)
            ns = np.ones(T, np.int64)
            n_max = 1
            masks = np.stack([self.switcher.mask(t) for t in range(T)])
            keys = rt._np_prng_keys(
                self.seed * 77_003 + np.arange(T, dtype=np.int64))
        sched = RoundSchedule(T, levels, ns, n_max, masks, keys)
        self._schedules[T] = sched
        return sched

    def init_carry(self):
        """The scan carry at round 0: ``(params, opt_state)`` (dynabro) or
        ``(params, worker_momenta)`` (momentum), device-placed per the
        session's sharding config."""
        params = self.params0
        if self.mode == "dynabro":
            if self.mesh is not None and "model" in self.mesh.axis_names:
                pin = rt._gspmd_constraints(self.mesh, self.worker_axis,
                                            self.param_specs)
                if pin is not None:
                    params = pin.put_params(params)
            return (params, self.opt.init(params))
        worker_m = jax.tree.map(
            lambda p: jnp.zeros((self.m,) + p.shape, jnp.float32), params)
        return (params, worker_m)

    def round_inputs(self, sched: RoundSchedule, t: int) -> RoundInputs:
        """Materialize round ``t``'s inputs from the schedule. Sampling is
        the direct per-round call — the reference the batch drivers'
        vectorized ``_batch_schedule`` is probe-checked against — so the
        padded batch tree is the one the offline scan consumes."""
        n = int(sched.ns[t])
        if self.mode == "dynabro":
            batches = rt._pad_units(self.sample_batches(t, n), sched.n_max,
                                    axis=1)
            return RoundInputs(t, int(sched.levels[t]), batches,
                               sched.masks[t], sched.keys[t])
        batches = jax.tree.map(lambda l: l[:, 0], self.sample_batches(t, 1))
        return RoundInputs(t, 0, batches, sched.masks[t], sched.keys[t])

    def _steady_guard(self, tag: str, xs, label: str):
        """A ``recompile_guard`` once this (tag, xs shapes/dtypes) signature
        has been seen (the first call with a signature is warmup: it may
        compile), else a null context that just records the signature."""
        if not self.guard_recompiles:
            return contextlib.nullcontext()
        sig: Tuple = (tag, self.mode) + tuple(jax.tree.leaves(
            jax.tree.map(lambda l: (tuple(l.shape), str(l.dtype)), xs)))
        if sig in self._steady_sigs:
            return sanitizers.recompile_guard(label)
        self._steady_sigs.add(sig)
        return contextlib.nullcontext()

    def step(self, carry, inputs: RoundInputs):
        """Advance one round: drive the compiled segment on a length-1
        schedule slice. Bitwise-identical to the same round inside a
        whole-``T`` ``run()`` (chunking invariance, DESIGN.md §5/§10).
        Returns ``(carry, StepInfo)``."""
        # every schedule() path emits int32 level plans (level_schedule and
        # the momentum zeros), so the step's trace signature is fixed a
        # priori — the old fallback consulted whichever schedule happened to
        # be cached first, tying the jit signature to cache insertion order
        one = lambda x: jnp.asarray(np.asarray(x)[None])  # noqa: E731
        if self.mode == "dynabro":
            xs = (jnp.asarray(np.asarray([inputs.level], dtype=np.int32)),
                  jax.tree.map(lambda l: jnp.asarray(l)[None], inputs.batches),
                  one(inputs.masks), one(inputs.key))
            with self._steady_guard("step", xs,
                                    f"Session.step (round {inputs.t})"):
                carry, (ok, dn) = self.scan_fn(carry, xs)
            info = StepInfo(failsafe_ok=bool(np.asarray(ok)[0]),
                            corr_norm=float(np.asarray(dn)[0]))
            sanitizers.maybe_assert_finite(
                {"params": carry[0], "corr_norm": dn},
                f"Session.step round {inputs.t}", enabled=self.nan_tripwire)
            return carry, info
        xs = (jax.tree.map(lambda l: jnp.asarray(l)[None], inputs.batches),
              one(inputs.masks), one(inputs.key))
        with self._steady_guard("step", xs,
                                f"Session.step (round {inputs.t})"):
            carry, _ = self.scan_fn(carry, xs)
        sanitizers.maybe_assert_finite(
            carry[0], f"Session.step round {inputs.t}",
            enabled=self.nan_tripwire)
        return carry, StepInfo()

    # ------------------------------------------------------------ drivers

    def run(self, T: int, *, eval_fn=None, eval_every: int = 0,
            chunk: int = 0, driver: str = "scan", step=None):
        """The whole-``T`` batch drivers. ``driver="scan"`` is the compiled
        chunked-``lax.scan`` loop; ``"legacy"`` the per-round jitted-step
        reference loop (the parity baseline — kept as a genuinely separate
        implementation). Returns ``(params, logs, evals)`` in dynabro mode
        and ``(params, evals)`` in momentum mode, exactly as the ``run_*``
        wrappers always did."""
        if driver not in ("scan", "legacy"):
            raise ValueError(
                f"unknown driver {driver!r}; expected 'scan' or 'legacy'")
        if driver == "legacy":
            if self.mesh is not None:
                raise ValueError("the legacy per-round driver runs unsharded;"
                                 " drop mesh= or use driver='scan'")
            if self.mode == "dynabro":
                return self._run_legacy_dynabro(T, eval_fn, eval_every, step)
            return self._run_legacy_momentum(T, eval_fn, eval_every, step)
        if self.mode == "dynabro":
            return self._run_scan_dynabro(T, eval_fn, eval_every, chunk)
        return self._run_scan_momentum(T, eval_fn, eval_every, chunk)

    def _run_scan_dynabro(self, T, eval_fn, eval_every, chunk):
        if T <= 0:
            return self.params0, [], []
        sched = self.schedule(T)
        scan_fn = self.scan_fn
        carry = self.init_carry()
        masks_dev = jnp.asarray(sched.masks)
        keys_dev = jnp.asarray(sched.keys)
        levels_dev = jnp.asarray(sched.levels)
        oks, evals = [], []
        a = 0
        for b in rt._segment_bounds(T, eval_every if eval_fn else 0, chunk):
            batches = rt._batch_schedule(
                self.sample_batches, list(zip(range(a, b), sched.ns[a:b])),
                sched.n_max, vectorize=self.vectorize_batches)
            xs = (levels_dev[a:b], batches, masks_dev[a:b], keys_dev[a:b])
            with self._steady_guard("run", xs,
                                    f"Session.run segment [{a}:{b}]"):
                carry, (ok, _dn) = scan_fn(carry, xs)
            oks.append(np.asarray(ok))
            sanitizers.maybe_assert_finite(
                carry[0], f"Session.run segment [{a}:{b}]",
                enabled=self.nan_tripwire)
            if eval_fn and eval_every and b % eval_every == 0:
                evals.append((b, eval_fn(carry[0], b - 1)))
            a = b
        ok_all = np.concatenate(oks) if oks else np.zeros(0, bool)
        return (carry[0],
                rt._round_logs(sched.levels, ok_all, sched.masks,
                               self.cfg.mlmc.j_max),
                evals)

    def _run_scan_momentum(self, T, eval_fn, eval_every, chunk):
        if T <= 0:
            return self.params0, []
        sched = self.schedule(T)
        masks = jnp.asarray(sched.masks)  # (T, m)
        keys = jnp.asarray(sched.keys)
        scan_fn = self.scan_fn
        carry = self.init_carry()
        evals = []
        a = 0
        for b in rt._segment_bounds(T, eval_every if eval_fn else 0, chunk):
            bsched = rt._batch_schedule(self.sample_batches,
                                        [(t, 1) for t in range(a, b)], 1,
                                        vectorize=self.vectorize_batches)
            batches = jax.tree.map(lambda l: l[:, :, 0], bsched)  # (L, m, ...)
            xs = (batches, masks[a:b], keys[a:b])
            with self._steady_guard("run", xs,
                                    f"Session.run segment [{a}:{b}]"):
                carry, _ = scan_fn(carry, xs)
            sanitizers.maybe_assert_finite(
                carry[0], f"Session.run segment [{a}:{b}]",
                enabled=self.nan_tripwire)
            if eval_fn and eval_every and b % eval_every == 0:
                evals.append((b, eval_fn(carry[0], b - 1)))
            a = b
        return carry[0], evals

    def _run_legacy_dynabro(self, T, eval_fn, eval_every, step):
        cfg, opt = self.cfg, self.opt
        rng = np.random.default_rng(self.seed)
        step = step or rt.make_dynabro_step(self.grad_fn, cfg, opt)
        params = self.params0
        opt_state = opt.init(params)
        logs, evals = [], []
        for t in range(T):
            j = sample_level(rng, cfg.mlmc.j_max) if cfg.use_mlmc else 0
            n = 2 ** j if (cfg.use_mlmc and j <= cfg.mlmc.j_max) else 1
            masks = np.stack([self.switcher.within_round(t, k)
                              for k in range(n)])
            batches = self.sample_batches(t, n)
            key = jax.random.PRNGKey(self.seed * 100_003 + t)
            params, opt_state, info = step(params, opt_state, batches,
                                           jnp.asarray(masks), key, j)
            logs.append(rt.RoundLog(j, bool(info["failsafe_ok"]),
                                    int(masks[0].sum()),
                                    round_cost(j, cfg.mlmc.j_max)))
            if eval_fn and eval_every and (t + 1) % eval_every == 0:
                evals.append((t + 1, eval_fn(params, t)))
        return params, logs, evals

    def _run_legacy_momentum(self, T, eval_fn, eval_every, step):
        step = step or rt.make_momentum_step(self.grad_fn, self.cfg, self.lr,
                                             self.beta)
        params = self.params0
        worker_m = jax.tree.map(
            lambda p: jnp.zeros((self.switcher.m,) + p.shape, jnp.float32),
            params)
        evals = []
        for t in range(T):
            mask = self.switcher.mask(t)
            batches = jax.tree.map(lambda l: l[:, 0],
                                   self.sample_batches(t, 1))
            key = jax.random.PRNGKey(self.seed * 77_003 + t)
            params, worker_m = step(params, worker_m, batches,
                                    jnp.asarray(mask), key)
            if eval_fn and eval_every and (t + 1) % eval_every == 0:
                evals.append((t + 1, eval_fn(params, t)))
        return params, evals

    # ------------------------------------------------------------- sweep

    def _sampler_for(self, seed: int):
        """The batch sampler of one replicate stream: ``sampler_factory``
        when the session carries one, else the bound ``sample_batches`` —
        valid only for the session's own seed, because per-replicate data
        streams must differ (DESIGN.md §12)."""
        if self.sampler_factory is not None:
            return self.sampler_factory(seed)
        if seed == self.seed:
            return self.sample_batches
        raise ValueError(
            "per-replicate batch streams need sampler_factory= (seed -> "
            "sample_batches); build the session with sampler_factory=, or "
            "via build_session with a Task whose make_sampler accepts "
            "sampler_seed=")

    def _sweep_streams(self, spec: SweepSpec, T: int):
        """The host-side schedule precompute shared by ``sweep`` and
        ``sweep_halving``: the session-seed level plan plus the
        per-replicate mask / key / batch streams (DESIGN.md §12). Masks come
        back ``(C, T, n_max, m)`` — or ``(C, R, T, n_max, m)`` when the spec
        replicates — keys ``(T, 2)`` / ``(R, T, 2)``."""
        cfg = self.cfg
        C = spec.lanes
        R = spec.n_replicates
        rep_seeds = spec.replicate_seeds(self.seed)
        replicated = R > 1
        levels, ns, n_max = rt._level_plan(
            cfg, np.random.default_rng(self.seed), T)
        sw_reps = [spec.resolve_switchers(self.m, s) for s in rep_seeds]
        if replicated:
            masks = np.stack([
                np.stack([rt._mask_schedule(sws[c], T, n_max, ns)
                          for sws in sw_reps])  # (R, T, n_max, m)
                for c in range(C)])              # -> (C, R, T, n_max, m)
            keys = np.stack([
                rt._np_prng_keys(s * 100_003 + np.arange(T, dtype=np.int64))
                for s in rep_seeds])             # (R, T, 2)
        else:
            masks = np.stack([rt._mask_schedule(sw, T, n_max, ns)
                              for sw in sw_reps[0]])
            keys = rt._np_prng_keys(
                rep_seeds[0] * 100_003 + np.arange(T, dtype=np.int64))
        samplers = [self._sampler_for(s) for s in rep_seeds]
        return (levels, ns, n_max, masks, keys, samplers, replicated,
                sw_reps[0][0].m if sw_reps[0] else self.m)

    def _sweep_batches(self, samplers, a: int, b: int, ns, n_max: int,
                       replicated: bool):
        """One segment's padded batch schedule: per-replicate schedules are
        stacked on a leading R axis (the inner vmap's mapped axis)."""
        tn = list(zip(range(a, b), ns[a:b]))
        if not replicated:
            return rt._batch_schedule(samplers[0], tn, n_max,
                                      vectorize=self.vectorize_batches)
        per_rep = [rt._batch_schedule(s, tn, n_max,
                                      vectorize=self.vectorize_batches)
                   for s in samplers]
        return jax.tree.map(lambda *ls: jnp.stack(ls), *per_rep)

    def _sweep_scan_fn(self, spec_scan_fn, cfg, atk_names, agg_names,
                       lane_mesh, lane_axis: str):
        """Build — or validate — the sweep's segment fn against the derived
        lane-axis branch sets and the (normalized) lane mesh."""
        lm = rt._norm_mesh(lane_mesh)
        if spec_scan_fn is None:
            return rt.make_dynabro_scan_fn(
                self.grad_fn, cfg, self.opt, lane_attacks=atk_names,
                lane_aggregators=agg_names, sweep_mesh=lm,
                lane_axis=lane_axis, worker_axis=self.worker_axis), lm
        scan_fn = spec_scan_fn
        if getattr(scan_fn, "worker_mesh", None) is not None:
            raise ValueError(
                "scan_fn was built with mesh=; vmapped sweeps run "
                "unsharded (DESIGN.md §7) — rebuild it without mesh")
        have_sm = rt._norm_mesh(getattr(scan_fn, "sweep_mesh", None))
        if have_sm != lm:
            raise ValueError(
                f"scan_fn was built with sweep_mesh={have_sm}, but this "
                f"sweep passes lane_mesh={lm}; rebuild it with "
                f"make_dynabro_scan_fn(..., sweep_mesh=...) to match")
        # the lane ids index the derived name tuples; a scan_fn whose
        # lax.switch branch order differs — or that lacks/adds a lane
        # axis — would silently apply the wrong attack or rule per lane
        for kind, want, arg in (
                ("lane_attacks", atk_names, "attacks"),
                ("lane_aggregators", agg_names, "aggregators")):
            have = getattr(scan_fn, kind, None)
            if have == want:
                continue
            if want is None:
                raise ValueError(
                    f"scan_fn was built with {kind}={have!r} but this "
                    f"sweep passes no {arg}; rebuild it without {kind} "
                    f"(or pass the per-lane {arg})")
            raise ValueError(
                f"scan_fn was built with {kind}={have!r} but this "
                f"sweep's {arg} derive {want!r}; rebuild it with "
                f"make_dynabro_scan_fn(..., {kind}={want!r})")
        return scan_fn, lm

    def _check_sweep_lane_mesh(self, lane_mesh, lane_axis: str, C: int,
                               m: Optional[int]):
        if lane_mesh is None:
            return
        rt._check_lane_mesh(lane_mesh, lane_axis, self.worker_axis, m)
        n_lanes = lane_mesh.shape[lane_axis]
        if C % n_lanes:
            raise ValueError(
                f"sweep cell count C={C} not divisible by the "
                f"{lane_axis!r} mesh axis size {n_lanes}")

    def sweep(self, spec: SweepSpec, T: int, *, chunk: int = 0,
              lane_chunk: int = 0, lane_mesh=None,
              lane_axis: str = "lanes") -> List[Any]:
        """Run ``spec.lanes`` cells as lanes of ONE vmapped compiled loop —
        the body behind ``run_dynabro_scan_sweep`` (see its docstring for
        the full lane/grouping/parity contracts, DESIGN.md §7). Mixed-rule
        grids recurse into branch-homogeneous sub-sweeps; results come back
        in the caller's lane order.

        With spec ``seeds=`` / ``replicates=`` every cell additionally runs
        one lane per replicate seed (DESIGN.md §12): masks, attack keys and
        batch draws follow the replicate seed (batches through the session's
        ``sampler_factory``), the MLMC level plan stays the session seed's
        (replicates are level-paired across cells), and the return value
        becomes a list over cells of per-replicate ``(params, logs)`` lists.
        With one replicate the flat ``[(params, logs), ...]`` shape — and,
        for the session's own seed, the exact schedule stream — of the
        un-replicated sweep is preserved.

        ``lane_chunk`` streams grids through fixed-size cell chunks (at most
        ``lane_chunk`` cells per dispatch, results accumulated host-side in
        caller order — chunking is bitwise-invariant, locked by
        tests/test_replicates.py). ``lane_mesh`` (a 2-axis
        ``launch.mesh.make_lane_mesh`` mesh) shards the cell axis — and,
        with a multi-device worker axis, each lane's per-worker gradients —
        across devices; a 1-device mesh is bitwise-identical to unsharded by
        construction. Requires the cell count divisible by the lane axis
        (per chunk, when combined with ``lane_chunk``)."""
        if self.mode != "dynabro":
            raise ValueError("sweeps are dynabro-mode only")
        spec = spec if isinstance(spec, SweepSpec) else SweepSpec(**spec)
        cfg, opt, params = self.cfg, self.opt, self.params0
        C = spec.lanes
        R = spec.n_replicates
        replicated = R > 1
        if C == 0:
            return []
        if T <= 0:
            return [[(params, [])] * R for _ in range(C)] if replicated \
                else [(params, []) for _ in range(C)]

        # ---- fixed-size lane chunks (DESIGN.md §12): split the cell axis
        # up front and accumulate per-chunk results host-side, so 1000+-cell
        # grids stream through bounded dispatches instead of one giant one
        if lane_chunk and lane_chunk > 0 and C > lane_chunk:
            outs: List[Any] = []
            for a in range(0, C, lane_chunk):
                sub = spec.lane_subset(range(a, min(a + lane_chunk, C)),
                                       scan_fn=spec.scan_fn)
                outs.extend(self.sweep(sub, T, chunk=chunk,
                                       lane_mesh=lane_mesh,
                                       lane_axis=lane_axis))
            return outs

        attacks = spec.attack_lanes()
        aggregators = spec.agg_lanes()
        scan_fn = spec.scan_fn

        # ---- branch-homogeneous lane grouping (DESIGN.md §7): split a
        # mixed-rule grid into one sub-sweep per distinct aggregator name, in
        # first-appearance order, and scatter results back to caller lane
        # order. Every schedule a sub-sweep derives (levels, keys, batches)
        # is a pure function of (cfg, seed, T), so the groups share them by
        # construction.
        group_fns = None
        if isinstance(scan_fn, Mapping):
            if aggregators is None:
                raise ValueError(
                    "scan_fn given as a {rule_name: scan_fn} mapping but "
                    "this sweep passes no aggregators to group by")
            group_fns = scan_fn
        if aggregators is not None:
            distinct = tuple(dict.fromkeys(name for name, _ in aggregators))
            # a superset mapping is fine — lane_chunk sub-sweeps may see only
            # a subset of the full grid's rules — but a missing key is a typo
            if group_fns is not None and not set(distinct) <= set(group_fns):
                raise ValueError(
                    f"scan_fn mapping keys {sorted(group_fns)} do not cover "
                    f"the grid's distinct aggregator names "
                    f"{sorted(distinct)}")
            if len(distinct) > 1 and (scan_fn is None
                                      or group_fns is not None):
                outs = [None] * C
                for name in distinct:
                    idx = [c for c in range(C)
                           if aggregators[c][0] == name]
                    sub = self.sweep(
                        spec.lane_subset(
                            idx, scan_fn=(None if group_fns is None
                                          else group_fns[name])),
                        T, chunk=chunk, lane_mesh=lane_mesh,
                        lane_axis=lane_axis)
                    for j, c in enumerate(idx):
                        outs[c] = sub[j]
                return outs
            if group_fns is not None:  # single distinct rule: unwrap and run
                scan_fn = group_fns[distinct[0]]

        (levels, ns, n_max, masks, keys, samplers, replicated,
         m) = self._sweep_streams(spec, T)
        self._check_sweep_lane_mesh(lane_mesh, lane_axis, C, m)
        atk = agg = atk_names = agg_names = None
        if attacks is not None:
            atk_names, ids, thetas = rt._lane_attack_plan(attacks)
            atk = (jnp.asarray(ids), jnp.asarray(thetas))
        if aggregators is not None:
            agg_names, gids, gthetas, coeffs = rt._lane_agg_plan(aggregators,
                                                                 cfg)
            agg = (jnp.asarray(gids), jnp.asarray(gthetas),
                   jnp.asarray(coeffs))
        lane_mode = atk is not None or agg is not None
        scan_fn, lm = self._sweep_scan_fn(scan_fn, cfg, atk_names, agg_names,
                                          lane_mesh, lane_axis)
        vseg = rt._vmapped_scan_fn(scan_fn, lane=lane_mode,
                                   replicated=replicated, lane_mesh=lm,
                                   lane_axis=lane_axis,
                                   worker_axis=self.worker_axis)

        def lanes(tree):  # identical initial state in every lane
            lead = (C, R) if replicated else (C,)
            return jax.tree.map(
                lambda l: jnp.broadcast_to(l, lead + l.shape), tree)

        carry = (lanes(params), lanes(opt.init(params)))
        masks_dev, keys_dev = jnp.asarray(masks), jnp.asarray(keys)
        levels_dev = jnp.asarray(levels)

        oks = []
        a = 0
        for b in rt._segment_bounds(T, 0, chunk):
            batches = self._sweep_batches(samplers, a, b, ns, n_max,
                                          replicated)
            if replicated:
                xs = (levels_dev[a:b], batches, masks_dev[:, :, a:b],
                      keys_dev[:, a:b])
            else:
                xs = (levels_dev[a:b], batches, masks_dev[:, a:b],
                      keys_dev[a:b])
            if lane_mode:
                carry, (ok, _dn) = vseg(carry, xs, atk, agg)
            else:
                carry, (ok, _dn) = vseg(carry, xs)
            oks.append(np.asarray(ok))  # (C, [R,] b - a)
            a = b
        ok_all = np.concatenate(oks, axis=-1)
        if not replicated:
            return [(jax.tree.map(lambda l, c=c: l[c], carry[0]),
                     rt._round_logs(levels, ok_all[c], masks[c],
                                    cfg.mlmc.j_max))
                    for c in range(C)]
        return [[(jax.tree.map(lambda l, c=c, r=r: l[c, r], carry[0]),
                  rt._round_logs(levels, ok_all[c, r], masks[c, r],
                                 cfg.mlmc.j_max))
                 for r in range(R)]
                for c in range(C)]

    def sweep_halving(self, spec: SweepSpec, T: int, *,
                      objective: Callable[[Any], float],
                      keep: float = 0.5, rungs=None, lane_mesh=None,
                      lane_axis: str = "lanes",
                      min_cells: int = 1) -> List[Dict[str, Any]]:
        """Adaptive successive-halving sweep (DESIGN.md §12): run every cell,
        and at each rung boundary prune the worst cells — scored by the mean
        of ``objective(params)`` (lower is better) over the cell's replicate
        lanes — keeping a ``keep`` fraction (at least ``min_cells``; NaN
        scores prune first). Survivors continue with their carries sliced to
        the surviving lanes, so a survivor's trajectory is bitwise-identical
        to a plain sweep of the surviving subset (lane-subset invariance,
        locked by tests/test_replicates.py).

        ``rungs`` is the increasing list of round counts at which to prune
        (default: one prune at ``T // 2``). Mixed-rule grids run as one
        multi-branch dispatch (no branch-homogeneous grouping — pruning
        scores are global across rules). Returns one dict per cell, in
        caller order: ``{"pruned": bool, "rounds_run": int, "results":
        [(params, logs), ...]}`` with one entry per replicate; a pruned
        cell's results are its state at the rung that dropped it."""
        if self.mode != "dynabro":
            raise ValueError("sweeps are dynabro-mode only")
        spec = spec if isinstance(spec, SweepSpec) else SweepSpec(**spec)
        if isinstance(spec.scan_fn, Mapping):
            raise ValueError(
                "sweep_halving runs mixed-rule grids as one multi-branch "
                "dispatch; pass a plain scan_fn (or None), not a "
                "{rule: scan_fn} mapping")
        cfg = self.cfg
        C = spec.lanes
        R = spec.n_replicates
        if C == 0:
            return []
        if T <= 0:
            raise ValueError("sweep_halving needs T >= 1")
        if not 0.0 < keep <= 1.0:
            raise ValueError(f"keep= must be in (0, 1], got {keep}")
        if rungs is None:
            rungs = [T // 2] if T >= 2 else []
        rungs = [int(r) for r in rungs]
        if any(not 0 < r < T for r in rungs) or \
                any(b <= a for a, b in zip(rungs, rungs[1:])):
            raise ValueError(
                f"rungs= must be strictly increasing round counts in "
                f"(0, T={T}), got {rungs}")

        attacks = spec.attack_lanes()
        aggregators = spec.agg_lanes()
        (levels, ns, n_max, masks, keys, samplers, replicated,
         m) = self._sweep_streams(spec, T)
        self._check_sweep_lane_mesh(lane_mesh, lane_axis, C, m)
        atk = agg = atk_names = agg_names = None
        if attacks is not None:
            atk_names, ids, thetas = rt._lane_attack_plan(attacks)
            atk = (jnp.asarray(ids), jnp.asarray(thetas))
        if aggregators is not None:
            agg_names, gids, gthetas, coeffs = rt._lane_agg_plan(aggregators,
                                                                 cfg)
            agg = (jnp.asarray(gids), jnp.asarray(gthetas),
                   jnp.asarray(coeffs))
        lane_mode = atk is not None or agg is not None
        scan_fn, lm = self._sweep_scan_fn(spec.scan_fn, cfg, atk_names,
                                          agg_names, lane_mesh, lane_axis)
        vseg = rt._vmapped_scan_fn(scan_fn, lane=lane_mode,
                                   replicated=replicated, lane_mesh=lm,
                                   lane_axis=lane_axis,
                                   worker_axis=self.worker_axis)
        n_lanes_mesh = lm.shape[lane_axis] if lm is not None else 1

        def lanes(tree):
            lead = (C, R) if replicated else (C,)
            return jax.tree.map(
                lambda l: jnp.broadcast_to(l, lead + l.shape), tree)

        def take(tree, idx):
            return jax.tree.map(lambda l: l[jnp.asarray(idx)], tree)

        def cell_out(carry, ok_rows, c_local: int, cell: int):
            """(params, logs) per replicate for local lane ``c_local``."""
            if not replicated:
                p = jax.tree.map(lambda l: l[c_local], carry[0])
                return [(p, rt._round_logs(levels[:ok_rows.shape[-1]],
                                           ok_rows[c_local], masks[cell],
                                           cfg.mlmc.j_max))]
            return [(jax.tree.map(lambda l, r=r: l[c_local, r], carry[0]),
                     rt._round_logs(levels[:ok_rows.shape[-1]],
                                    ok_rows[c_local, r], masks[cell, r],
                                    cfg.mlmc.j_max))
                    for r in range(R)]

        carry = (lanes(self.params0), lanes(self.opt.init(self.params0)))
        masks_dev, keys_dev = jnp.asarray(masks), jnp.asarray(keys)
        levels_dev = jnp.asarray(levels)
        alive = list(range(C))  # original cell index per live lane
        outs: List[Optional[Dict[str, Any]]] = [None] * C
        oks: List[np.ndarray] = []
        a = 0
        for b in rungs + [T]:
            batches = self._sweep_batches(samplers, a, b, ns, n_max,
                                          replicated)
            if replicated:
                xs = (levels_dev[a:b], batches,
                      masks_dev[jnp.asarray(alive)][:, :, a:b],
                      keys_dev[:, a:b])
            else:
                xs = (levels_dev[a:b], batches,
                      masks_dev[jnp.asarray(alive)][:, a:b], keys_dev[a:b])
            if lane_mode:
                carry, (ok, _dn) = vseg(carry, xs, atk, agg)
            else:
                carry, (ok, _dn) = vseg(carry, xs)
            oks.append(np.asarray(ok))
            ok_all = np.concatenate(oks, axis=-1)  # (C_live, [R,] b)
            if b == T:
                break
            # ---- prune: mean objective over replicates, lower is better
            finals = np.array(
                [[float(objective(p)) for p, _ in
                  cell_out(carry, ok_all, j, cell)]
                 for j, cell in enumerate(alive)])
            scores = np.where(np.isnan(finals), np.inf, finals).mean(axis=1)
            k = max(int(min_cells), int(np.ceil(len(alive) * keep)))
            if n_lanes_mesh > 1:  # keep the lane axis divisible
                k = max(n_lanes_mesh,
                        int(np.ceil(k / n_lanes_mesh)) * n_lanes_mesh)
            k = min(k, len(alive))
            order = np.argsort(scores, kind="stable")
            keep_local = sorted(int(j) for j in order[:k])
            if len(keep_local) < len(alive):
                for j, cell in enumerate(alive):
                    if j not in set(keep_local):
                        outs[cell] = {
                            "pruned": True, "rounds_run": b,
                            "results": cell_out(carry, ok_all, j, cell)}
                carry = (take(carry[0], keep_local),
                         take(carry[1], keep_local))
                if lane_mode:
                    atk = None if atk is None else take(atk, keep_local)
                    agg = None if agg is None else take(agg, keep_local)
                oks = [o[np.asarray(keep_local)] for o in oks]
                alive = [alive[j] for j in keep_local]
            a = b
        ok_all = np.concatenate(oks, axis=-1)
        for j, cell in enumerate(alive):
            outs[cell] = {"pruned": False, "rounds_run": T,
                          "results": cell_out(carry, ok_all, j, cell)}
        return outs


def _task_sampler_factory(task, m: int):
    """A seed -> sampler factory from a Task whose ``make_sampler`` accepts
    ``sampler_seed=`` (the replicate-axis data-stream hook, DESIGN.md §12);
    ``None`` when the task cannot re-seed its sampler."""
    try:
        params = inspect.signature(task.make_sampler).parameters
    except (TypeError, ValueError):
        return None
    if "sampler_seed" not in params:
        return None
    return lambda s: task.make_sampler(m, sampler_seed=s)


def build_session(cfg, task=None, *, m: Optional[int] = None,
                  switcher: Optional[Switcher] = None, **kw) -> Session:
    """The facade constructor: ``build_session(cfg, task) -> Session``.

    ``task`` (a ``scenarios.Task``) supplies ``grad_fn`` / ``params0`` and —
    given a worker count via ``m=`` or ``switcher=`` — the batch sampler
    (plus, when ``task.make_sampler`` accepts ``sampler_seed=``, the
    per-replicate ``sampler_factory`` the sweep's seed axis needs); any
    Session kwarg can override or extend it. Without a task, pass
    ``grad_fn=`` / ``params0=`` / ``sample_batches=`` directly."""
    if m is None and switcher is not None:
        m = switcher.m
    if task is not None:
        kw.setdefault("grad_fn", task.grad_fn)
        kw.setdefault("params0", task.params0)
        if m is not None:
            kw.setdefault("sample_batches", task.make_sampler(m))
            factory = _task_sampler_factory(task, m)
            if factory is not None:
                kw.setdefault("sampler_factory", factory)
    return Session(cfg, switcher=switcher, m=m, **kw)
