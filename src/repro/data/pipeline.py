"""Deterministic synthetic data pipelines.

* ``SyntheticLMData`` — language-model token streams with a learnable
  structure (Zipf-ish marginals + local bigram correlations) so loss actually
  decreases; shardable per (worker, round, microbatch) with no host state.
* ``gaussian_mixture_dataset`` — the classification task used by the paper
  reproduction benchmarks (MNIST/CIFAR stand-in at matched scale: homogeneous
  workers sampling i.i.d. from the same distribution).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def _tokens(self, key, batch) -> jax.Array:
        k1, k2 = jax.random.split(key)
        # zipf-ish marginal via squared uniform; bigram structure via rolling mix
        u = jax.random.uniform(k1, (batch, self.seq_len))
        base = (u * u * self.vocab_size).astype(jnp.int32)
        copy = jax.random.bernoulli(k2, 0.3, (batch, self.seq_len))
        rolled = jnp.roll(base, 1, axis=1)
        return jnp.where(copy, rolled, base) % self.vocab_size

    def batch(self, step: int, batch: int | None = None) -> dict:
        batch = batch or self.global_batch
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        toks = self._tokens(key, batch)
        labels = jnp.roll(toks, -1, axis=1)
        return {"tokens": toks, "labels": labels}

    def worker_batch(self, step: int, worker: int, batch: int) -> dict:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), worker)
        toks = self._tokens(key, batch)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


def gaussian_mixture_dataset(n_classes: int, dim: int, n: int, seed: int = 0,
                             noise: float = 1.0) -> Tuple[np.ndarray, np.ndarray]:
    """Fixed class means on a sphere, isotropic noise. Returns (X, y)."""
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(n_classes, dim))
    means /= np.linalg.norm(means, axis=1, keepdims=True)
    means *= 3.0
    y = rng.integers(0, n_classes, size=n)
    X = means[y] + noise * rng.normal(size=(n, dim))
    return X.astype(np.float32), y.astype(np.int32)
