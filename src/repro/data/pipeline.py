"""Deterministic synthetic data pipelines.

* ``SyntheticLMData`` — language-model token streams with a learnable
  structure (Zipf-ish marginals + local bigram correlations) so loss actually
  decreases; shardable per (worker, round, microbatch) with no host state.
* ``gaussian_mixture_dataset`` — the classification task used by the paper
  reproduction benchmarks (MNIST/CIFAR stand-in at matched scale: homogeneous
  workers sampling i.i.d. from the same distribution).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def _tokens(self, key, batch) -> jax.Array:
        k1, k2 = jax.random.split(key)
        # zipf-ish marginal via squared uniform; bigram structure via rolling mix
        u = jax.random.uniform(k1, (batch, self.seq_len))
        base = (u * u * self.vocab_size).astype(jnp.int32)
        copy = jax.random.bernoulli(k2, 0.3, (batch, self.seq_len))
        rolled = jnp.roll(base, 1, axis=1)
        return jnp.where(copy, rolled, base) % self.vocab_size

    def batch(self, step: int, batch: int | None = None) -> dict:
        # `batch or global_batch` would silently promote an explicit 0
        if batch is None:
            batch = self.global_batch
        if batch <= 0:
            raise ValueError(f"batch must be positive, got {batch}")
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        toks = self._tokens(key, batch)
        labels = jnp.roll(toks, -1, axis=1)
        return {"tokens": toks, "labels": labels}

    def worker_batch(self, step: int, worker: int, batch: int) -> dict:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), worker)
        toks = self._tokens(key, batch)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

    def mlmc_batches(self, step, m: int, n: int, unit_batch: int) -> dict:
        """(m, n, unit_batch, S) token/label trees for one DynaBRO round.

        Unit (w, k) is keyed on ``fold_in(fold_in(fold_in(seed, step), w), k)``
        — a pure function of (step, worker, within-round index), so the
        level-(j−1) mini-batch is the prefix of the level-j one (the MLMC
        nesting, DESIGN.md §3) and the sampler is traceable in ``step``, which
        lets ``run_dynabro_scan`` vectorize the whole batch schedule."""
        if unit_batch <= 0:
            raise ValueError(f"unit_batch must be positive, got {unit_batch}")
        base = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)

        def unit(w, k):
            kk = jax.random.fold_in(jax.random.fold_in(base, w), k)
            return self._tokens(kk, unit_batch)

        toks = jax.vmap(lambda w: jax.vmap(lambda k: unit(w, k))(
            jnp.arange(n)))(jnp.arange(m))
        return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=3)}

    def mlmc_sampler(self, m: int, unit_batch: int = 1):
        """``sample_batches(t, n)`` closure for the DynaBRO drivers."""
        return lambda t, n: self.mlmc_batches(t, m, n, unit_batch)


def gaussian_mixture_dataset(n_classes: int, dim: int, n: int, seed: int = 0,
                             noise: float = 1.0) -> Tuple[np.ndarray, np.ndarray]:
    """Fixed class means on a sphere, isotropic noise. Returns (X, y)."""
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(n_classes, dim))
    means /= np.linalg.norm(means, axis=1, keepdims=True)
    means *= 3.0
    y = rng.integers(0, n_classes, size=n)
    X = means[y] + noise * rng.normal(size=(n, dim))
    return X.astype(np.float32), y.astype(np.int32)
