from repro.data.pipeline import SyntheticLMData, gaussian_mixture_dataset

__all__ = ["SyntheticLMData", "gaussian_mixture_dataset"]
