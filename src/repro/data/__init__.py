from repro.data.classification import (
    clf_logits, clf_loss, init_clf, make_task,
)
from repro.data.pipeline import SyntheticLMData, gaussian_mixture_dataset

__all__ = ["SyntheticLMData", "gaussian_mixture_dataset",
           "init_clf", "clf_logits", "clf_loss", "make_task"]
