"""The classification testbed of the Section-6 experiments: a small tanh
MLP on the synthetic Gaussian-mixture dataset (no downloads offline), with a
per-unit gradient fn and the deterministic index sampler the drivers expect.

Lives in the package (not under ``benchmarks/``) so the examples and the
quickstart run with a plain ``pip install -e .``; ``benchmarks._clf`` re-
exports it for the benchmark modules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.pipeline import gaussian_mixture_dataset

N_CLASSES = 10
DIM = 64
HIDDEN = 128


def init_clf(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (DIM, HIDDEN)) * (1 / DIM ** 0.5),
        "b1": jnp.zeros(HIDDEN),
        "w2": jax.random.normal(k2, (HIDDEN, N_CLASSES)) * (1 / HIDDEN ** 0.5),
        "b2": jnp.zeros(N_CLASSES),
    }


def clf_logits(params, x):
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def clf_loss(params, batch):
    x, y = batch
    logits = clf_logits(params, x)
    return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y])


def make_index_sampler(m: int, unit_batch: int = 32, seed: int = 0,
                       n_train: int = 20000):
    """The deterministic training-index sampler of ``make_task``, standalone
    so the replicate-seed axis can fold a distinct draw stream per replicate
    (DESIGN.md §12) while the dataset itself stays fixed: a
    ``(t, k) -> (m, k, unit_batch)`` index tensor seeded by ``seed``."""
    def sampler(t, k):
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 17), t)
        return jax.random.randint(key, (m, k, unit_batch), 0, n_train)
    return sampler


def make_task(m: int, unit_batch: int = 32, seed: int = 0, noise: float = 1.0):
    """Returns (params0, grad_fn, sampler, eval_fn)."""
    X, y = gaussian_mixture_dataset(N_CLASSES, DIM, 24000, seed=seed,
                                    noise=noise)
    Xtr, ytr = X[:20000], y[:20000]
    Xte, yte = X[20000:], y[20000:]
    Xtr, ytr = jnp.asarray(Xtr), jnp.asarray(ytr)
    Xte, yte = jnp.asarray(Xte), jnp.asarray(yte)
    n = Xtr.shape[0]

    def grad_fn(params, idx):
        return jax.grad(clf_loss)(params, (Xtr[idx], ytr[idx]))

    sampler = make_index_sampler(m, unit_batch, seed=seed, n_train=n)

    @jax.jit
    def test_acc(params):
        return jnp.mean(jnp.argmax(clf_logits(params, Xte), -1) == yte)

    def eval_fn(params, t):
        return {"test_acc": float(test_acc(params))}

    return init_clf(jax.random.PRNGKey(seed)), grad_fn, sampler, eval_fn
