"""Model-zoo DynaBRO tasks (DESIGN.md §9).

Wraps a reduced real architecture (any ``configs`` arch id) as a
``core.scenarios.Task``, so the compiled scan driver runs the zoo through
the SAME path as the quadratic testbed: ``run_dynabro_scan(make_zoo_task(
"smollm-360m", ...))`` — with ``mesh=(workers, 'model')``, ``param_specs``
from ``launch.sharding.plan_params`` and ``microbatch=True`` — is the
unified Mode-A/Mode-B driver. Unit batches follow the nested-prefix MLMC
keying of ``SyntheticLMData.mlmc_batches`` (level j−1 is the prefix of
level j), and audio/vlm families get their ``extra`` leaves from the same
per-unit key stream, so the nesting property holds for every family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, get_reduced_config
from repro.core.scenarios import Task
from repro.data.pipeline import SyntheticLMData
from repro.models import init_params, loss_fn


def _extra_units(cfg: ModelConfig, key, m: int, n: int, unit_batch: int,
                 dtype):
    """(m, n, unit_batch, E, D) encoder inputs for audio/vlm families, keyed
    ``fold_in(fold_in(key, w), k)`` per unit — the same nested scheme as the
    token stream, so the MLMC prefix property survives the extra leaves."""
    if cfg.family == "audio":
        name, E = "frames", cfg.encoder_seq
    else:
        name, E = "patches", cfg.n_image_tokens

    def unit(w, k):
        kk = jax.random.fold_in(jax.random.fold_in(key, w), k)
        return jax.random.normal(kk, (unit_batch, E, cfg.d_model), dtype)

    grid = jax.vmap(lambda w: jax.vmap(lambda k: unit(w, k))(jnp.arange(n)))(
        jnp.arange(m))
    return {name: grid}


def make_zoo_task(arch_id: str, *, seq_len: int = 32, unit_batch: int = 1,
                  d_model: int = 64, n_layers: int = 2,
                  dtype=jnp.float32, seed: int = 0):
    """Returns ``(Task, ModelConfig)`` for a reduced ``arch_id``.

    The Task's ``grad_fn`` is the per-unit ``jax.grad`` of the model's own
    ``loss_fn``; its ``make_sampler(m)`` draws (m, n, unit_batch, S)
    token/label (+ family ``extra``) grids traceable in t, so the scan
    driver vectorizes the batch schedule. The config rides along for
    ``plan_params`` (the zoo driver's ``param_specs``) and eval plumbing.
    """
    cfg = get_reduced_config(arch_id, d_model=d_model, n_layers=n_layers)
    params0 = init_params(cfg, jax.random.PRNGKey(seed), dtype)
    data = SyntheticLMData(cfg.vocab_size, seq_len, global_batch=unit_batch,
                           seed=seed)
    has_extra = cfg.family in ("audio", "vlm")
    ekey = jax.random.PRNGKey(seed ^ 0x5EED)

    def grad_fn(params, b):
        return jax.grad(lambda p: loss_fn(p, b, cfg))(params)

    def make_sampler(m: int):
        base = data.mlmc_sampler(m, unit_batch)

        def sample(t, n):
            b = base(t, n)
            if has_extra:
                b["extra"] = _extra_units(
                    cfg, jax.random.fold_in(ekey, t), m, n, unit_batch, dtype)
            return b

        return sample

    # fixed held-out batch (a step index no training round reaches)
    eval_b = data.batch(999_983, 4)
    if has_extra:
        ex = _extra_units(cfg, jax.random.fold_in(ekey, -1), 1, 1, 4, dtype)
        eval_b["extra"] = jax.tree.map(lambda l: l[0, 0], ex)

    def objective(p) -> float:
        return float(loss_fn(p, eval_b, cfg))

    return Task(params0, grad_fn, make_sampler, objective), cfg
