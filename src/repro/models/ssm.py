"""State-space mixers: Mamba (S6 selective scan) and RWKV-6 (Finch) time-mix.

Mamba's selective scan is chunked: a sequential ``lax.scan`` over sequence
chunks carrying the SSM state, with a parallel ``associative_scan`` inside
each chunk — this bounds the (B, L, d_inner, d_state) temporaries to
(B, chunk, d_inner, d_state) while keeping log-depth within the chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.scan_compat import scan as _scan

from repro.models.layers import group_norm_heads

# ================================================================ Mamba


def _ssm_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def selective_scan(x, delta, A, B, C, D, h0=None, chunk: int = 256):
    """h_t = exp(dt*A) h_{t-1} + dt*B_t*x_t ; y_t = C_t . h_t + D*x_t.

    x, delta: (Bt, L, di); A: (di, ds); B, C: (Bt, L, ds); D: (di,).
    Returns (y (Bt,L,di), h_last (Bt,di,ds)).
    """
    Bt, L, di = x.shape
    ds = A.shape[1]
    chunk = min(chunk, L)
    Lp = -(-L // chunk) * chunk
    pad = Lp - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = Lp // chunk
    xs = x.reshape(Bt, nc, chunk, di)
    dts = delta.reshape(Bt, nc, chunk, di)
    Bs = B.reshape(Bt, nc, chunk, ds)
    Cs = C.reshape(Bt, nc, chunk, ds)
    if h0 is None:
        h0 = jnp.zeros((Bt, di, ds), jnp.float32)

    # remat the chunk body: autodiff of the scan would otherwise save the
    # (Bt, chunk, di, ds) discretized a/b/h_all temporaries for every chunk —
    # the dominant train-memory term for mamba archs (§Perf iteration 4).
    # With checkpointing only the (Bt, di, ds) carry is kept per chunk.
    @jax.checkpoint
    def body_fn(h, chunk_in):
        xc, dt, Bc, Cc = (t.astype(jnp.float32) for t in chunk_in)
        a = jnp.exp(dt[..., None] * A[None, None])  # (Bt, c, di, ds)
        b = (dt * xc)[..., None] * Bc[:, :, None, :]
        ca, cb = lax.associative_scan(_ssm_combine, (a, b), axis=1)
        h_all = ca * h[:, None] + cb  # (Bt, c, di, ds)
        y = jnp.einsum("bcds,bcs->bcd", h_all, Cc) + D[None, None] * xc
        return h_all[:, -1], y.astype(x.dtype)

    def body(h, ci):
        return body_fn(h, (xs[:, ci], dts[:, ci], Bs[:, ci], Cs[:, ci]))

    h_last, ys = _scan(body, h0, jnp.arange(nc))
    y = ys.transpose(1, 0, 2, 3).reshape(Bt, Lp, di)[:, :L]
    return y, h_last


def selective_step(x, delta, A, B, C, D, h):
    """Single decode step. x/delta: (Bt, di); B/C: (Bt, ds); h: (Bt, di, ds)."""
    xf = x.astype(jnp.float32)
    dt = delta.astype(jnp.float32)
    a = jnp.exp(dt[..., None] * A[None])
    b = (dt * xf)[..., None] * B[:, None, :].astype(jnp.float32)
    h = a * h + b
    y = jnp.einsum("bds,bs->bd", h, C.astype(jnp.float32)) + D[None] * xf
    return y.astype(x.dtype), h


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (Bt, L, di), w: (k, di) -> (Bt, L, di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = lax.conv_general_dilated(
        xp, w[:, None, :],  # (k, 1, di) kernel: (spatial, in_per_group, out)
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=x.shape[-1])
    return out + b


def mamba_mixer(x, p, cfg, cache=None, pos=None):
    """Mamba block. x: (Bt, L, D). cache: dict(conv (Bt,k-1,di), ssm (Bt,di,ds))
    for decode (L==1). Returns (y, new_cache)."""
    Bt, L, Dm = x.shape
    di = cfg.mamba_expand * cfg.d_model
    ds = cfg.mamba_d_state
    xz = x @ p["in_proj"]  # (Bt, L, 2*di)
    xi_raw, z = jnp.split(xz, 2, axis=-1)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, ds)
    if cache is None:
        xi = jax.nn.silu(_causal_conv(xi_raw, p["conv_w"], p["conv_b"]))
        dbc = xi @ p["x_proj"]  # (Bt, L, dt_rank + 2*ds)
        dt_rank = p["dt_proj"].shape[0]
        dt, Bssm, Cssm = jnp.split(dbc, [dt_rank, dt_rank + ds], axis=-1)
        delta = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])
        y, h_last = selective_scan(xi, delta, A, Bssm, Cssm, p["D"])
        # prefill cache: conv state = last k-1 raw conv inputs, ssm = final state
        k = p["conv_w"].shape[0]
        tail = xi_raw[:, -(k - 1):]
        pad = (k - 1) - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        new_cache = {"conv": tail, "ssm": h_last}
    else:
        # decode: L == 1
        conv_st = cache["conv"].astype(xi_raw.dtype)  # (Bt, k-1, di)
        xin = jnp.concatenate([conv_st, xi_raw], axis=1)  # (Bt, k, di)
        xc = jnp.einsum("bkd,kd->bd", xin, p["conv_w"]) + p["conv_b"]
        xc = jax.nn.silu(xc)
        dbc = xc @ p["x_proj"]
        dt_rank = p["dt_proj"].shape[0]
        dt, Bssm, Cssm = jnp.split(dbc, [dt_rank, dt_rank + ds], axis=-1)
        delta = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])
        yb, h = selective_step(xc, delta, A, Bssm, Cssm, p["D"], cache["ssm"])
        y = yb[:, None]
        new_cache = {"conv": xin[:, 1:], "ssm": h}
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], new_cache


# ================================================================ RWKV-6


def _rwkv_decay(xw, p):
    """Data-dependent per-channel decay: w = exp(-exp(w0 + tanh(x@w1)@w2))."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w1"]) @ p["w2"]
    return jnp.exp(-jnp.exp(p["w0"] + lora))  # (..., D) in (0,1)


def _rwkv_wkv_scan(r, k, v, w, u, s0):
    """Sequential wkv. r/k/v/w: (Bt, L, H, hd); u: (H, hd); s0: (Bt, H, hd, hd).

    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    y_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t)
    """
    def body(S, inp):
        rt, kt, vt, wt = inp  # (Bt, H, hd)
        kv = kt[..., :, None] * vt[..., None, :]  # (Bt, H, hd, hd)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    seq = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
           v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    S, ys = _scan(body, s0, seq)
    return ys.transpose(1, 0, 2, 3), S  # (Bt, L, H, hd)


def rwkv_time_mix(x, p, cfg, cache=None):
    """RWKV-6 time mixing. x: (Bt, L, D) post-norm input.

    cache (decode): dict(prev (Bt, D), state (Bt, H, hd, hd)).
    Returns (y, new_cache).
    """
    Bt, L, Dm = x.shape
    hd = cfg.rwkv_head_dim
    H = Dm // hd
    if cache is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        s0 = jnp.zeros((Bt, H, hd, hd), jnp.float32)
    else:
        prev = cache["prev"][:, None]
        s0 = cache["state"]
    d = prev - x
    xr = x + d * p["mu_r"]
    xk = x + d * p["mu_k"]
    xv = x + d * p["mu_v"]
    xw = x + d * p["mu_w"]
    xg = x + d * p["mu_g"]
    r = (xr @ p["wr"]).reshape(Bt, L, H, hd)
    k = (xk @ p["wk"]).reshape(Bt, L, H, hd)
    v = (xv @ p["wv"]).reshape(Bt, L, H, hd)
    g = jax.nn.silu(xg @ p["wg"])
    w = _rwkv_decay(xw, p).reshape(Bt, L, H, hd)
    u = p["u"].reshape(H, hd)
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    y, S = _rwkv_wkv_scan(rf, kf, vf, wf, u, s0)
    y = group_norm_heads(y, p["ln_x"].reshape(H, hd)).reshape(Bt, L, Dm)
    y = (y.astype(x.dtype) * g) @ p["wo"]
    new_cache = {"prev": x[:, -1], "state": S}
    return y, new_cache


def rwkv_channel_mix(x, p, cache=None):
    """RWKV channel mix. x: (Bt, L, D). cache: dict(prev (Bt,D))."""
    if cache is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = cache["prev"][:, None]
    d = prev - x
    xk = x + d * p["mu_k"]
    xr = x + d * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    return out, {"prev": x[:, -1]}
