"""Memory-optimal attention: custom-VJP online-softmax with recompute backward.

JAX autodiff of the online-softmax scan in ``layers.chunked_attention`` saves
per-chunk residuals (probability blocks and accumulator carries) — O(S²/chunk)
memory per layer, which dominated the baseline train_4k dry-runs (§Perf).
This implementation saves only (q, k, v, out, lse) and recomputes probability
blocks in the backward pass from the logsumexp — the FlashAttention recipe in
pure JAX.

Sharding: the scan runs over KV chunks only; the full q-sequence axis stays a
plain tensor dimension, so it can be sharded across the model axis
(``shard_axis='model'``) when attention heads don't divide it — this removed
the per-chunk score all-reduces that dominated the baseline collective term
(65536 × 640 MB for qwen2.5-32b prefill; see EXPERIMENTS.md §Perf). K/V are
small (KV-head count × hd) and are left to replicate per layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.scan_compat import scan as _scan
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def _maybe_shard(x, spec):
    if spec is None:
        return x
    from repro.models import scan_compat
    if scan_compat.unrolling_active():
        # legacy Mode B (partial-manual shard_map on jax <= 0.4.x): a
        # Sharding annotation here lacks the manual subgroup and trips the
        # SPMD partitioner (DESIGN.md §3) — drop the perf hint, keep math.
        return x
    try:
        return lax.with_sharding_constraint(x, P(*spec))
    except Exception:  # no ambient mesh (plain CPU tests)
        return x


def _pad_to(x, n, axis):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, n - x.shape[axis])
    return jnp.pad(x, pad) if n != x.shape[axis] else x


def _mask(qpos, kpos, causal, window, kv_valid):
    m = kpos[None, :] < kv_valid
    if causal:
        m = m & (kpos[None, :] <= qpos[:, None])
    if window:
        m = m & (kpos[None, :] > (qpos[:, None] - window))
    return m  # (Sq, kc)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    q_offset: int = 0, kv_chunk: int = 1024,
                    shard_axis: str = "", batch_axis: str = ""):
    """q: (B,Sq,H,hd), k/v: (B,Skv,KV,hd) -> (B,Sq,H,hd). GQA supported.

    ``shard_axis``: mesh axis to shard the q-sequence dimension over;
    ``batch_axis``: mesh axis the batch dim stays sharded over (inference) —
    omitting it would force batch replication (measured §Perf iteration 2)."""
    out, _ = _fwd_impl(q, k, v, causal, window, q_offset, kv_chunk, shard_axis,
                       batch_axis)
    return out


def _q_spec(shard_axis, batch_axis=""):
    if not shard_axis and not batch_axis:
        return None
    return (batch_axis or None, None, None, shard_axis or None, None)


def _fwd_impl(q, k, v, causal, window, q_offset, kv_chunk, shard_axis,
              batch_axis=""):
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    kc = min(kv_chunk, Skv)
    Skp = -(-Skv // kc) * kc
    nk = Skp // kc
    qh = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4)  # (B,KV,G,Sq,hd)
    qh = _maybe_shard(qh, _q_spec(shard_axis, batch_axis))
    kp = _pad_to(k, Skp, 1).reshape(B, nk, kc, KV, hd)
    vp = _pad_to(v, Skp, 1).reshape(B, nk, kc, KV, hd)
    qpos = q_offset + jnp.arange(Sq)

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = _maybe_shard(jnp.zeros((B, KV, G, Sq, hd), jnp.float32),
                      _q_spec(shard_axis, batch_axis))

    def kv_body(carry, ki):
        m, l, acc = carry
        kpos = ki * kc + jnp.arange(kc)
        s = jnp.einsum("bkgqd,bskd->bkgqs", qh, kp[:, ki],
                       preferred_element_type=jnp.float32) * scale
        msk = _mask(qpos, kpos, causal, window, Skv)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vp.dtype), vp[:, ki],
                        preferred_element_type=jnp.float32)
        return (m_new, l_new, acc * corr[..., None] + pv), None

    (m, l, acc), _ = _scan(kv_body, (m0, l0, a0), jnp.arange(nk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KV,G,Sq,hd)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)
    return out, lse


def _fwd(q, k, v, causal, window, q_offset, kv_chunk, shard_axis, batch_axis):
    out, lse = _fwd_impl(q, k, v, causal, window, q_offset, kv_chunk, shard_axis,
                         batch_axis)
    return out, (q, k, v, out, lse)


def _bwd(causal, window, q_offset, kv_chunk, shard_axis, batch_axis, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    kc = min(kv_chunk, Skv)
    Skp = -(-Skv // kc) * kc
    nk = Skp // kc
    qh = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4)
    qh = _maybe_shard(qh, _q_spec(shard_axis, batch_axis))
    doh = dout.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4).astype(jnp.float32)
    doh = _maybe_shard(doh, _q_spec(shard_axis, batch_axis))
    oh = out.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4).astype(jnp.float32)
    kp = _pad_to(k, Skp, 1).reshape(B, nk, kc, KV, hd)
    vp = _pad_to(v, Skp, 1).reshape(B, nk, kc, KV, hd)
    qpos = q_offset + jnp.arange(Sq)
    Drow = jnp.sum(doh * oh, axis=-1)  # (B,KV,G,Sq)

    dq0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    dq0 = _maybe_shard(dq0, _q_spec(shard_axis, batch_axis))

    def kv_body(dq_acc, ki):
        kpos = ki * kc + jnp.arange(kc)
        s = jnp.einsum("bkgqd,bskd->bkgqs", qh, kp[:, ki],
                       preferred_element_type=jnp.float32) * scale
        msk = _mask(qpos, kpos, causal, window, Skv)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # recomputed probabilities
        dp = jnp.einsum("bkgqd,bskd->bkgqs", doh.astype(v.dtype), vp[:, ki],
                        preferred_element_type=jnp.float32)
        ds = p * (dp - Drow[..., None]) * scale
        dq_c = jnp.einsum("bkgqs,bskd->bkgqd", ds.astype(k.dtype), kp[:, ki],
                          preferred_element_type=jnp.float32)
        dk_c = jnp.einsum("bkgqs,bkgqd->bskd", ds.astype(q.dtype), qh,
                          preferred_element_type=jnp.float32)
        dv_c = jnp.einsum("bkgqs,bkgqd->bskd", p.astype(jnp.float32), doh,
                          preferred_element_type=jnp.float32)
        return dq_acc + dq_c, (dk_c, dv_c)

    dq, (dks, dvs) = _scan(kv_body, dq0, jnp.arange(nk))
    dq = dq.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Skp, KV, hd)[:, :Skv]
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Skp, KV, hd)[:, :Skv]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_fwd, _bwd)
