"""Legacy-jax scan compatibility for Mode B.

XLA bundled with jax <= 0.4.x cannot propagate partial-manual shardings
through ``while`` loops (sharding propagation check-fails on the
ManualSubgroup invariant), so any ``lax.scan`` reached from inside the
partial-manual shard_map region of Mode B must lower to straight-line HLO.

``forward`` enters ``unrolled_scans()`` when a param hook is active on legacy
jax; every model scan routed through :func:`scan` then unrolls at trace time.
Outside that extent (Mode A, inference, new jax) it is ``lax.scan`` verbatim.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
from jax import lax

_UNROLL = False


def unrolling_active() -> bool:
    return _UNROLL


@contextlib.contextmanager
def unrolled_scans(enable: bool = True):
    global _UNROLL
    prev = _UNROLL
    _UNROLL = _UNROLL or enable
    try:
        yield
    finally:
        _UNROLL = prev


def scan(body, init, xs):
    """Drop-in for ``lax.scan(body, init, xs)`` honoring ``unrolled_scans``."""
    if not _UNROLL:
        return lax.scan(body, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda l: l[i], xs))
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    else:
        stacked = ys[0] if ys else None
    return carry, stacked
