"""GShard/Switch-style Mixture-of-Experts FFN with capacity-based dispatch.

Dense einsum dispatch (tokens x experts x capacity one-hots) — the standard
TPU-friendly formulation: expert dim shards over the data axis
(expert-parallel) and the ff dim over the model axis.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _capacity(tokens: int, top_k: int, factor: float, E: int) -> int:
    """Per-expert token capacity ⌊tokens·k·factor/E⌋, nudged so f64
    representation error cannot truncate an exact boundary one token short
    (int(0.3 * 10) == 2) — the local twin of ``agg_engine.count_floor``
    (models/ stays import-independent of core/)."""
    # jaxlint: disable=JXL003 -- sanctioned nudged-floor helper, see docstring
    return max(1, math.floor(tokens * top_k * factor / E + 1e-5))


def _topk_dispatch(probs: jax.Array, top_k: int, capacity: int):
    """probs: (N, E) -> dispatch (N, E, C) float, combine (N, E, C) float, aux."""
    from repro.models import scan_compat
    N, E = probs.shape
    if scan_compat.unrolling_active():
        # legacy Mode B: the sort partitioner reshards its input to a plain
        # {replicated} sharding, dropping the manual subgroup (XLA check-
        # fail, DESIGN.md §3) — take the top_k by iterated argmax instead
        # (top_k is 1–4; argmax lowers to a plain reduce)
        masked, cols = jax.lax.stop_gradient(probs), []
        for _ in range(top_k):
            i = jnp.argmax(masked, axis=-1)  # (N,)
            cols.append(i)
            masked = masked - jax.nn.one_hot(i, E, dtype=masked.dtype) * 1e9
        idx = jnp.stack(cols, axis=-1)  # (N, k)
    else:
        idx = jax.lax.top_k(probs, top_k)[1]  # (N, k) indices only
    # gates re-read probs via one-hots rather than using top_k's value
    # output: the transpose is then a matmul, not a scatter
    gates = jnp.einsum("nke,ne->nk", jax.nn.one_hot(idx, E, dtype=probs.dtype),
                       probs)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    dispatch = jnp.zeros((N, E, capacity), probs.dtype)
    combine = jnp.zeros((N, E, capacity), probs.dtype)
    counts = jnp.zeros((E,), jnp.int32)
    frac_dispatched = jnp.zeros((E,), jnp.float32)
    if scan_compat.unrolling_active():
        # legacy Mode B: cumsum lowers to ReduceWindow, which the partial-
        # manual SPMD partitioner rejects — associative_scan lowers to
        # log-depth pad/add instead (DESIGN.md §3)
        def csum(a):
            return jax.lax.associative_scan(jnp.add, a, axis=0)
    else:
        def csum(a):
            return jnp.cumsum(a, axis=0)
    for k in range(top_k):
        m = jax.nn.one_hot(idx[:, k], E, dtype=jnp.int32)  # (N, E)
        pos = csum(m) - m + counts[None, :]  # position within expert
        counts = counts + m.sum(0)
        keep = (pos < capacity) & (m > 0)
        oh_pos = jax.nn.one_hot(pos, capacity, dtype=probs.dtype)  # (N, E, C)
        slot = keep.astype(probs.dtype)[..., None] * oh_pos
        dispatch = dispatch + slot
        combine = combine + slot * gates[:, k][:, None, None]
        frac_dispatched = frac_dispatched + m.astype(jnp.float32).mean(0)
    # load-balance aux loss (Switch/GShard): E * sum_e mean_prob_e * mean_dispatch_e
    aux = E * jnp.sum(probs.astype(jnp.float32).mean(0) * frac_dispatched / max(top_k, 1))
    return dispatch, combine, aux


def _moe_group(xf: jax.Array, p: dict, top_k: int, capacity: int, act: str,
               expert_shard: str = ""):
    """One token group through the experts. xf: (N, D) -> (N, D), aux.

    ``expert_shard``: mesh axis to pin the expert dim of the dispatched
    activations to (expert parallelism) — without the constraint XLA may
    all-gather the expert weights instead (§Perf iteration 3)."""
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    dispatch, combine, aux = _topk_dispatch(probs, top_k, capacity)
    dispatch = dispatch.astype(xf.dtype)
    combine = combine.astype(xf.dtype)

    def pin(t):
        if not expert_shard:
            return t
        from repro.models.flash import _maybe_shard
        return _maybe_shard(t, (expert_shard,) + (None,) * (t.ndim - 1))

    xs = pin(jnp.einsum("nec,nd->ecd", dispatch, xf))  # (E, C, D)
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, p["we1"]))
        h = h * jnp.einsum("ecd,edf->ecf", xs, p["we3"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xs, p["we1"]))
    ys = pin(jnp.einsum("ecf,efd->ecd", pin(h), p["we2"]))  # (E, C, D)
    return jnp.einsum("nec,ecd->nd", combine, ys), aux


def moe_ffn(x: jax.Array, p: dict, *, top_k: int, capacity_factor: float,
            act: str = "swiglu", token_group: int = 0,
            expert_shard: str = "") -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D). p: router (D,E), we1/we3 (E,D,F), we2 (E,F,D).

    ``token_group`` > 0 routes tokens in independent groups of that size
    (GShard-style grouping): the (N, E, C) dispatch one-hots are then
    O(group·E·C_group) instead of O(N·E·C) ~ N² — essential at prefill scale.
    Returns (out (B,S,D), aux_loss scalar).
    """
    B, S, D = x.shape
    E = p["router"].shape[1]
    N = B * S
    xf = x.reshape(N, D)
    if S == 1:  # decode: tiny token count — guarantee zero drops
        out, aux = _moe_group(xf, p, top_k, N, act, expert_shard)
        return out.reshape(B, S, D), aux
    if token_group and N > token_group and N % token_group == 0:
        g = N // token_group
        capacity = _capacity(token_group, top_k, capacity_factor, E)
        xg = xf.reshape(g, token_group, D)
        # vmap (not scan): keeps the group axis a shardable tensor dim
        out, auxs = jax.vmap(
            lambda xc: _moe_group(xc, p, top_k, capacity, act, expert_shard))(xg)
        return out.reshape(B, S, D), auxs.mean()
    capacity = _capacity(N, top_k, capacity_factor, E)
    out, aux = _moe_group(xf, p, top_k, capacity, act, expert_shard)
    return out.reshape(B, S, D), aux
