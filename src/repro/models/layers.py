"""Primitive layers: norms, RoPE, chunked (flash-style) attention, MLPs.

Attention is written as an online-softmax scan over KV chunks so that the
lowered HLO never materializes an (S, S) score matrix — this is what keeps the
train_4k / prefill_32k dry-runs inside the per-chip HBM budget.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.scan_compat import scan as _scan

# ---------------------------------------------------------------- norms


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * lax.rsqrt(var + eps) * scale + bias).astype(dtype)


def apply_norm(x, p, kind: str):
    if kind == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def group_norm_heads(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Per-head groupnorm used by RWKV time-mix output. x: (..., H, hd)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * lax.rsqrt(var + eps) * scale).astype(dtype)


# ---------------------------------------------------------------- RoPE


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions: (...,) int -> cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (S, hd//2) or broadcastable."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    # broadcast cos/sin over batch/head axes: (S, half) -> (1, S, 1, half)
    while cos.ndim < x1.ndim:
        cos, sin = cos[None], sin[None]
        if cos.ndim == x1.ndim - 1:  # insert head axis before last
            cos = jnp.expand_dims(cos, -2)
            sin = jnp.expand_dims(sin, -2)
            break
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------- attention

NEG_INF = -1e30


def _attn_chunk(qc, kc, vc, qpos, kpos, *, causal, window, scale, m, l, acc,
                bias=None, kv_len=None):
    """One online-softmax update. qc: (B,Q,KV,G,hd) kc/vc: (B,S,KV,hd)."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc, preferred_element_type=jnp.float32)
    s = s * scale
    if bias is not None:
        s = s + bias
    mask = kpos[None, :] >= 0  # also masks padded kv slots (kpos = INTMAX-tagged)
    if kv_len is not None:
        mask = kpos[None, :] < kv_len
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window:
        mask = mask & (kpos[None, :] > (qpos[:, None] - window))
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vc.dtype), vc,
                    preferred_element_type=jnp.float32)
    acc_new = acc * corr[..., None] + pv
    return m_new, l_new, acc_new


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Flash-style attention. q: (B,Sq,H,hd), k/v: (B,Skv,KV,hd) -> (B,Sq,H,hd).

    GQA via reshaping q heads into (KV, G). Memory is O(chunk^2), not O(S^2).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    qc_n = min(q_chunk, Sq)
    kc_n = min(kv_chunk, Skv)
    # pad to multiples
    Sq_p = -(-Sq // qc_n) * qc_n
    Skv_p = -(-Skv // kc_n) * kc_n
    qp = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    qp = qp.reshape(B, Sq_p // qc_n, qc_n, KV, G, hd)
    kp = kp.reshape(B, Skv_p // kc_n, kc_n, KV, hd)
    vp = vp.reshape(B, Skv_p // kc_n, kc_n, KV, hd)
    kv_valid = Skv  # mask padded kv positions via kpos >= Skv

    def q_body(_, qi):
        qcb = qp[:, qi]  # (B, qc, KV, G, hd)
        qpos = q_offset + qi * qc_n + jnp.arange(qc_n)
        m0 = jnp.full((B, KV, G, qc_n), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc_n), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc_n, hd), jnp.float32)

        def kv_body(carry, ki):
            m, l, acc = carry
            kpos = ki * kc_n + jnp.arange(kc_n)
            m, l, acc = _attn_chunk(
                qcb, kp[:, ki], vp[:, ki], qpos, kpos,
                causal=causal, window=window, scale=scale, m=m, l=l, acc=acc,
                kv_len=kv_valid)
            return (m, l, acc), None

        (m, l, acc), _ = _scan(kv_body, (m0, l0, a0), jnp.arange(Skv_p // kc_n))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, KV, G, qc, hd) -> (B, qc, KV*G, hd)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, qc_n, H, hd)
        return None, out.astype(q.dtype)

    _, outs = _scan(q_body, None, jnp.arange(Sq_p // qc_n))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq_p, H, hd)
    return out[:, :Sq]


def decode_attention(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, S, KV, hd)
    v_cache: jax.Array,
    length_mask: Optional[jax.Array] = None,  # (B, S) bool, True = valid
) -> jax.Array:
    """Single-token attention against a KV cache (B,1,H,hd)."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    qh = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qh, k_cache, preferred_element_type=jnp.float32)
    s = s * scale
    if length_mask is not None:
        s = jnp.where(length_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------- MLP


def mlp(x: jax.Array, p: dict, act: str) -> jax.Array:
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(x @ p["w1"] + p.get("b1", 0))
    out = h @ p["w2"]
    if "b2" in p:
        out = out + p["b2"]
    return out
