"""Composable decoder stack covering all assigned families.

A model is a repeated *layer group* (``cfg.pattern()``): the stack lowers as a
single ``lax.scan`` over ``n_groups`` stacked parameter groups, so HLO size is
independent of depth (72–100 layer archs compile like 1-group models).

Three entry points, matching the assigned input shapes:
  * ``loss_fn``      — training step objective (train_4k)
  * ``prefill``      — forward + KV/state cache construction (prefill_32k)
  * ``decode_step``  — one token against a seq_len cache (decode_32k, long_500k)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import scan_compat, ssm
from repro.models.layers import (
    apply_norm, rms_norm, rope_angles, apply_rope,
    chunked_attention, decode_attention, mlp,
)
from repro.models.moe import moe_ffn

Params = Dict[str, Any]

# ================================================================ init


def _norm_params(cfg, d, key=None):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def _dense(key, shape, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _attn_params(cfg: ModelConfig, key, dtype, cross=False) -> Params:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "ln": _norm_params(cfg, D),
        "wq": _dense(ks[0], (D, H * hd), dtype),
        "wk": _dense(ks[1], (D, KV * hd), dtype),
        "wv": _dense(ks[2], (D, KV * hd), dtype),
        "wo": _dense(ks[3], (H * hd, D), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _mlp_params(cfg, key, dtype, d_ff=None) -> Params:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w1": _dense(ks[0], (D, F), dtype), "w2": _dense(ks[1], (F, D), dtype)}
    if cfg.act == "swiglu":
        p["w3"] = _dense(ks[2], (D, F), dtype)
    return p


def _moe_params(cfg, key, dtype) -> Params:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": _dense(ks[0], (D, E), jnp.float32),
        "we1": _dense(ks[1], (E, D, F), dtype, scale=1.0 / math.sqrt(D)),
        "we2": _dense(ks[2], (E, F, D), dtype, scale=1.0 / math.sqrt(F)),
    }
    if cfg.act == "swiglu":
        p["we3"] = _dense(ks[3], (E, D, F), dtype, scale=1.0 / math.sqrt(D))
    if cfg.n_shared_experts:
        p["shared"] = _mlp_params(cfg, jax.random.fold_in(key, 7), dtype,
                                  d_ff=cfg.shared_d_ff)
    return p


def _mamba_params(cfg, key, dtype) -> Params:
    D = cfg.d_model
    di = cfg.mamba_expand * D
    ds = cfg.mamba_d_state
    dt_rank = max(1, D // 16)
    k = cfg.mamba_conv
    ks = jax.random.split(key, 5)
    return {
        "ln": _norm_params(cfg, D),
        "in_proj": _dense(ks[0], (D, 2 * di), dtype),
        "conv_w": _dense(ks[1], (k, di), dtype, scale=1.0 / math.sqrt(k)),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _dense(ks[2], (di, dt_rank + 2 * ds), dtype),
        "dt_proj": _dense(ks[3], (dt_rank, di), dtype),
        "dt_bias": jnp.full((di,), math.log(math.e ** 0.01 - 1), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _dense(ks[4], (di, D), dtype),
    }


def _rwkv_params(cfg, key, dtype) -> Params:
    D = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = D // hd
    lr = 64
    ks = jax.random.split(key, 8)
    p = {"ln": _norm_params(cfg, D)}
    for i, n in enumerate(("wr", "wk", "wv", "wg", "wo")):
        p[n] = _dense(ks[i], (D, D), dtype)
    for n in ("mu_r", "mu_k", "mu_v", "mu_w", "mu_g"):
        p[n] = jnp.full((D,), 0.5, dtype)
    p["w0"] = jnp.full((D,), -2.0, jnp.float32)
    p["w1"] = _dense(ks[5], (D, lr), jnp.float32)
    p["w2"] = _dense(ks[6], (lr, D), jnp.float32, scale=0.01)
    p["u"] = jnp.zeros((D,), jnp.float32)
    p["ln_x"] = jnp.ones((D,), jnp.float32)
    return p


def _cmix_params(cfg, key, dtype) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "ln": _norm_params(cfg, D),
        "mu_k": jnp.full((D,), 0.5, dtype),
        "mu_r": jnp.full((D,), 0.5, dtype),
        "wk": _dense(ks[0], (D, F), dtype),
        "wv": _dense(ks[1], (F, D), dtype),
        "wr": _dense(ks[2], (D, D), dtype),
    }


def _block_params(cfg, mixer, mlp_kind, key, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {}
    if mixer in ("attn", "cross_attn"):
        p["mix"] = _attn_params(cfg, k1, dtype, cross=(mixer == "cross_attn"))
        if cfg.family == "audio":  # whisper decoder: self + cross per layer
            p["cross"] = _attn_params(cfg, k3, dtype, cross=True)
    elif mixer == "mamba":
        p["mix"] = _mamba_params(cfg, k1, dtype)
    elif mixer == "rwkv":
        p["mix"] = _rwkv_params(cfg, k1, dtype)
    if mlp_kind == "rwkv_cmix":
        p["mlp"] = _cmix_params(cfg, k2, dtype)
    else:
        q = {"ln": _norm_params(cfg, cfg.d_model)}
        if mlp_kind in ("moe", "moe+dense"):
            q["moe"] = _moe_params(cfg, k2, dtype)
            if mlp_kind == "moe+dense":
                q["dense"] = _mlp_params(cfg, jax.random.fold_in(k2, 3), dtype)
        else:
            q["dense"] = _mlp_params(cfg, k2, dtype)
        p["mlp"] = q
    return p


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> Params:
    ke, ku, kb, kenc = jax.random.split(key, 4)
    D, V = cfg.d_model, cfg.vocab_size
    params: Params = {
        "embed": _dense(ke, (V, D), dtype, scale=0.02),
        "final_norm": _norm_params(cfg, D),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _dense(ku, (D, V), dtype)
    pattern = cfg.pattern()

    def one_group(gkey):
        gks = jax.random.split(gkey, len(pattern))
        return {f"b{i}": _block_params(cfg, mixer, mk, gks[i], dtype)
                for i, (mixer, mk) in enumerate(pattern)}

    gkeys = jax.random.split(kb, cfg.n_groups)
    params["blocks"] = jax.vmap(one_group)(gkeys)

    if cfg.family == "audio":
        # encoder stack (bidirectional attn + mlp), stacked over enc layers
        def enc_layer(k):
            return {"attn": _attn_params(cfg, k, dtype),
                    "mlp": {"ln": _norm_params(cfg, D),
                            "dense": _mlp_params(cfg, jax.random.fold_in(k, 1), dtype)}}
        eks = jax.random.split(kenc, cfg.n_encoder_layers)
        params["encoder"] = {"blocks": jax.vmap(enc_layer)(eks),
                             "final_norm": _norm_params(cfg, D)}
        params["dec_pos"] = _dense(jax.random.fold_in(kenc, 2), (32768, D), dtype, scale=0.02)
    return params


# ================================================================ blocks


def _attn_apply(x, p, cfg: ModelConfig, *, cross=False, kv_src=None, causal=True,
                pos_offset=0, cache=None, pos=None, mode="train", pad_to=0):
    """Returns (x_out, cache_out). cache_out: prefill -> new kv; decode -> updated."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = apply_norm(x, p["ln"], cfg.norm)
    q = h @ p["wq"] + (p["bq"] if "bq" in p else 0)
    q = q.reshape(B, S, H, hd)
    use_rope = cfg.family != "audio" and not cross

    if cross and mode == "decode":
        ck, cv = cache["k"], cache["v"]
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"])
        out = decode_attention(q, ck, cv)
        new_cache = cache
    else:
        src = kv_src if cross else h
        k = (src @ p["wk"] + (p["bk"] if "bk" in p else 0)).reshape(B, -1, KV, hd)
        v = (src @ p["wv"] + (p["bv"] if "bv" in p else 0)).reshape(B, -1, KV, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"])
            k = rms_norm(k, p["k_norm"])
        if mode == "decode":
            # self-attention, single token against ring-buffer cache
            Sc = cache["k"].shape[1]
            if use_rope:
                cos, sin = rope_angles(pos[None], hd, cfg.rope_theta)
                q = apply_rope(q, cos, sin)
                k = apply_rope(k, cos, sin)
            idx = (pos % Sc).astype(jnp.int32)
            kc = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, idx, 0, 0))
            vc = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, idx, 0, 0))
            valid = jnp.arange(Sc) < jnp.minimum(pos + 1, Sc)
            out = decode_attention(q, kc, vc, valid[None].repeat(B, 0))
            new_cache = {"k": kc, "v": vc}
        else:
            if use_rope:
                cos, sin = rope_angles(pos_offset + jnp.arange(S), hd, cfg.rope_theta)
                q = apply_rope(q, cos, sin)
                k = apply_rope(k, cos, sin)
            window = 0 if cross else cfg.sliding_window
            if cfg.attn_impl == "flash":
                from repro.models.flash import flash_attention
                out = flash_attention(q, k, v, causal and not cross, window,
                                      0, 1024, cfg.attn_seq_shard,
                                      cfg.attn_batch_shard)
            else:
                out = chunked_attention(q, k, v, causal=causal and not cross,
                                        window=window)
            new_cache = None
            if mode == "prefill":
                if cfg.sliding_window and not cross:
                    wk = k[:, -cfg.sliding_window:]
                    wv = v[:, -cfg.sliding_window:]
                    new_cache = {"k": wk, "v": wv}
                else:
                    if pad_to and not cross and pad_to > k.shape[1]:
                        padw = ((0, 0), (0, pad_to - k.shape[1]), (0, 0), (0, 0))
                        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
                    new_cache = {"k": k, "v": v}
    out = out.reshape(B, S, H * hd) @ p["wo"]
    return x + out, new_cache


def _mlp_apply(x, p, cfg: ModelConfig, mlp_kind, *, cache=None, mode="train"):
    """Returns (x_out, aux, cache_out)."""
    aux = jnp.zeros((), jnp.float32)
    if mlp_kind == "rwkv_cmix":
        h = apply_norm(x, p["ln"], cfg.norm)
        dcache = cache if mode == "decode" else None
        out, new_cache = ssm.rwkv_channel_mix(h, p, dcache)
        if mode == "train":
            new_cache = None
        return x + out, aux, new_cache
    h = apply_norm(x, p["ln"], cfg.norm)
    out = 0.0
    if "moe" in p:
        moe_out, aux = moe_ffn(h, p["moe"], top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor, act=cfg.act,
                               token_group=cfg.moe_token_group,
                               expert_shard=cfg.moe_expert_shard)
        out = out + moe_out
        if "shared" in p["moe"]:
            out = out + mlp(h, p["moe"]["shared"], cfg.act)
    if "dense" in p:
        out = out + mlp(h, p["dense"], cfg.act)
    return x + out, aux, None


def _block_apply(x, p, cfg: ModelConfig, mixer, mlp_kind, *, kv_src=None,
                 cache=None, pos=None, pos_offset=0, mode="train", pad_to=0):
    """Returns (x, aux, cache_out)."""
    cache = cache or {}
    cache_out = {}
    if mixer in ("attn", "cross_attn"):
        is_cross = mixer == "cross_attn"
        x, c = _attn_apply(x, p["mix"], cfg, cross=is_cross, kv_src=kv_src,
                           pos_offset=pos_offset, cache=cache.get("mix"),
                           pos=pos, mode=mode, pad_to=pad_to)
        if c is not None:
            cache_out["mix"] = c
        if cfg.family == "audio":  # whisper decoder adds cross-attn
            x, c2 = _attn_apply(x, p["cross"], cfg, cross=True, kv_src=kv_src,
                                cache=cache.get("cross"), pos=pos, mode=mode)
            if c2 is not None:
                cache_out["cross"] = c2
    elif mixer == "mamba":
        h = apply_norm(x, p["mix"]["ln"], cfg.norm)
        dcache = cache.get("mix") if mode == "decode" else None
        out, c = ssm.mamba_mixer(h, p["mix"], cfg, cache=dcache)
        if mode in ("decode", "prefill"):
            cache_out["mix"] = jax.tree.map(lambda a: a, c)
        x = x + out
    elif mixer == "rwkv":
        h = apply_norm(x, p["mix"]["ln"], cfg.norm)
        dcache = cache.get("mix") if mode == "decode" else None
        out, c = ssm.rwkv_time_mix(h, p["mix"], cfg, cache=dcache)
        if mode in ("decode", "prefill"):
            cache_out["mix"] = c
        x = x + out
    x, aux, c = _mlp_apply(x, p["mlp"], cfg, mlp_kind,
                           cache=cache.get("mlp"), mode=mode)
    if c is not None:
        cache_out["mlp"] = c
    return x, aux, cache_out


# ================================================================ stacks


def _encoder_forward(params, frames, cfg: ModelConfig):
    """Whisper encoder over stubbed frame embeddings (B, Senc, D)."""
    S = frames.shape[1]
    D = cfg.d_model
    # sinusoidal positions
    pos = jnp.arange(S)[:, None]
    dim = jnp.arange(D // 2)[None, :]
    ang = pos / (10000 ** (2 * dim / D))
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(frames.dtype)
    x = frames + pe[None]

    def body(x, p):
        x, _ = _attn_apply(x, p["attn"], cfg, causal=False, mode="train")
        x, _, _ = _mlp_apply(x, p["mlp"], cfg, "dense", mode="train")
        return x, None

    x, _ = lax.scan(body, x, params["encoder"]["blocks"])
    return apply_norm(x, params["encoder"]["final_norm"], cfg.norm)


# jax <= 0.4.x: XLA sharding propagation cannot handle gather/scatter HLOs
# inside a partial-manual shard_map region (hlo_sharding_util IsManualSubgroup
# check-fail), so Mode B (param_hook active) swaps the token gathers for
# one-hot matmuls on that path — gather-free, and so is the transpose.
from repro.compat import LEGACY_PARTIAL_MANUAL as _LEGACY_PARTIAL_MANUAL


def _embed_tokens(params, tokens, cfg, pos=None, gatherless=False):
    if gatherless:
        onehot = jax.nn.one_hot(tokens, params["embed"].shape[0],
                                dtype=params["embed"].dtype)
        x = onehot @ params["embed"]
    else:
        x = params["embed"][tokens]
    if cfg.family == "audio":
        if pos is not None:  # decode: single absolute position
            x = x + lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0)[None]
        else:
            S = tokens.shape[1]
            x = x + params["dec_pos"][:S][None]
    return x


def _unembed(params, x, cfg):
    x = apply_norm(x, params["final_norm"], cfg.norm)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return x @ w


def _kv_src(params, cfg, extra):
    if cfg.family == "audio":
        return _encoder_forward(params, extra["frames"], cfg)
    if cfg.family == "vlm":
        return extra["patches"]
    return None


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            extra: Optional[dict] = None, mode: str = "train",
            remat: bool = True, pad_to: int = 0, param_hook=None):
    """Full causal forward. Returns (logits, aux) in train mode, and
    (logits, aux, cache) in prefill mode.

    ``param_hook(subtree, scope)`` — optional transform applied to parameters
    at point of use (scope 'top' once; scope 'blocks' per scanned group).
    Mode B threads the robust-aggregating FSDP all-gather through this."""
    if param_hook is not None:
        top = {k: v for k, v in params.items() if k != "blocks"}
        params = {**param_hook(top, "top"), "blocks": params["blocks"]}
    x = _embed_tokens(params, tokens, cfg,
                      gatherless=param_hook is not None and _LEGACY_PARTIAL_MANUAL)
    kv_src = _kv_src(params, cfg, extra or {})
    pattern = cfg.pattern()

    def _stream_constraint(x):
        # keep the residual stream (batch, seq)-sharded so per-layer XLA
        # choices can't silently replicate it (§Perf iteration 2)
        if cfg.attn_seq_shard or cfg.attn_batch_shard:
            from repro.models.flash import _maybe_shard
            x = _maybe_shard(x, (cfg.attn_batch_shard or None,
                                 cfg.attn_seq_shard or None, None))
        return x

    def group_body(x, gp):
        if param_hook is not None:
            gp = param_hook(gp, "blocks")
        aux = jnp.zeros((), jnp.float32)
        caches = {}
        x = _stream_constraint(x)
        for i, (mixer, mk) in enumerate(pattern):
            x, a, c = _block_apply(x, gp[f"b{i}"], cfg, mixer, mk,
                                   kv_src=kv_src, mode=mode, pad_to=pad_to)
            aux = aux + a
            # jaxlint: disable=JXL002 -- c is a host dict of cache leaves; its truthiness is static pytree structure, not a traced value
            if c:
                caches[f"b{i}"] = c
        return x, (aux, caches)

    body = group_body
    if remat and mode == "train":
        body = jax.checkpoint(group_body, prevent_cse=False)
    # Mode B on legacy jax: every scan below here must unroll (while loops
    # cannot carry partial-manual shardings through XLA <= 0.4.x — see
    # models.scan_compat); covers this group loop and the attention/SSM
    # chunk scans inside the blocks.
    with scan_compat.unrolled_scans(
            param_hook is not None and _LEGACY_PARTIAL_MANUAL):
        x, (auxs, caches) = scan_compat.scan(body, x, params["blocks"])
    logits = _unembed(params, x, cfg)
    aux = jnp.sum(auxs)
    if mode == "prefill":
        return logits, aux, caches
    return logits, aux


def loss_fn(params: Params, batch: dict, cfg: ModelConfig, param_hook=None) -> jax.Array:
    """Mean next-token cross-entropy + router aux."""
    logits, aux = forward(params, batch["tokens"], cfg, extra=batch.get("extra"),
                          param_hook=param_hook)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    if param_hook is not None and _LEGACY_PARTIAL_MANUAL:
        gold = jnp.sum(logits * jax.nn.one_hot(labels, logits.shape[-1],
                                               dtype=logits.dtype), axis=-1)
    else:
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold).mean()
    return nll + cfg.router_aux_weight * aux


# ---------------------------------------------------------------- serving


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16) -> Params:
    """Cache pytree for decode; leaves stacked over n_groups."""
    KV, hd, D = cfg.n_kv_heads, cfg.hd, cfg.d_model
    S_eff = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len

    def one_group():
        c = {}
        for i, (mixer, mk) in enumerate(cfg.pattern()):
            e = {}
            if mixer == "attn":
                e["mix"] = {"k": jnp.zeros((batch, S_eff, KV, hd), dtype),
                            "v": jnp.zeros((batch, S_eff, KV, hd), dtype)}
                if cfg.family == "audio":
                    e["cross"] = {"k": jnp.zeros((batch, cfg.encoder_seq, KV, hd), dtype),
                                  "v": jnp.zeros((batch, cfg.encoder_seq, KV, hd), dtype)}
            elif mixer == "cross_attn":
                e["mix"] = {"k": jnp.zeros((batch, cfg.n_image_tokens, KV, hd), dtype),
                            "v": jnp.zeros((batch, cfg.n_image_tokens, KV, hd), dtype)}
            elif mixer == "mamba":
                di = cfg.mamba_expand * D
                e["mix"] = {"conv": jnp.zeros((batch, cfg.mamba_conv - 1, di), dtype),
                            "ssm": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32)}
            elif mixer == "rwkv":
                H = D // cfg.rwkv_head_dim
                e["mix"] = {"prev": jnp.zeros((batch, D), dtype),
                            "state": jnp.zeros((batch, H, cfg.rwkv_head_dim,
                                                cfg.rwkv_head_dim), jnp.float32)}
            if mk == "rwkv_cmix":
                e["mlp"] = {"prev": jnp.zeros((batch, D), dtype)}
            c[f"b{i}"] = e
        return c

    one = one_group()
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (cfg.n_groups,) + x.shape), one)


def decode_step(params: Params, cache: Params, token: jax.Array, pos: jax.Array,
                cfg: ModelConfig) -> Tuple[jax.Array, Params]:
    """One serving step. token: (B,) int32; pos: scalar int32 (tokens so far).

    Returns (logits (B, V), new_cache)."""
    x = _embed_tokens(params, token[:, None], cfg, pos=pos)
    pattern = cfg.pattern()

    def group_body(x, gp_cache):
        gp, gc = gp_cache
        new_c = {}
        for i, (mixer, mk) in enumerate(pattern):
            x, _, c = _block_apply(x, gp[f"b{i}"], cfg, mixer, mk,
                                   cache=gc.get(f"b{i}", {}), pos=pos, mode="decode")
            new_c[f"b{i}"] = c
        return x, new_c

    x, new_cache = lax.scan(group_body, x, (params["blocks"], cache))
    logits = _unembed(params, x, cfg)
    return logits[:, 0], new_cache


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
            extra: Optional[dict] = None, pad_to: int = 0):
    """Prefill pass: returns (last-position logits, cache).

    ``pad_to`` grows self-attention KV caches to this many slots so that
    subsequent ``decode_step`` calls append instead of ring-overwriting."""
    logits, _, cache = forward(params, tokens, cfg, extra=extra, mode="prefill",
                               pad_to=pad_to)
    return logits[:, -1], cache
