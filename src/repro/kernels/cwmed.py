"""Coordinate-wise median / trimmed-mean kernels — now stages of the fused
one-pass kernel in ``fused.py``; this module re-exports the single-stage
forms so existing imports keep working. See fused.py for the kernel body
(the bitonic row-sort network lives there too)."""
from repro.kernels.fused import (  # noqa: F401
    _INF,
    _bitonic_sort_rows,
    cwmed,
    cwtm,
    cwtm_masked,
)

__all__ = ["cwmed", "cwtm", "cwtm_masked"]
