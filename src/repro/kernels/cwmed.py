"""Pallas TPU kernel: coordinate-wise median / trimmed mean over m workers.

Layout: input (m, d) with m small (16/32 workers) and d huge (up to 4.8e11/m
coordinates per device after the worker all-to-all). The grid tiles d; each
step loads an (m, TILE_D) block into VMEM and sorts the m rows with a bitonic
sorting network (min/max row swaps — no data-dependent control flow, VPU
friendly), then emits the middle row(s) (median) or the trimmed row mean.

The m axis is padded to the next power of two with +inf rows so the network
is shape-static; statistics index only the valid prefix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INF = 3.0e38  # python float: becomes a kernel-local constant, not a capture


def _bitonic_sort_rows(rows):
    """Sort a list of (TILE_D,) f32 rows ascending, element-wise (each
    coordinate sorted independently across rows). len(rows) must be a power
    of two. Returns the sorted list."""
    n = len(rows)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            for i in range(n):
                l = i ^ j
                if l > i:
                    up = (i & k) == 0
                    a, b = rows[i], rows[l]
                    lo = jnp.minimum(a, b)
                    hi = jnp.maximum(a, b)
                    rows[i] = lo if up else hi
                    rows[l] = hi if up else lo
            j //= 2
        k *= 2
    return rows


def _sorted_rows(x_ref, m: int):
    mp = 1 << (m - 1).bit_length()
    rows = [x_ref[i, :].astype(jnp.float32) for i in range(m)]
    rows += [jnp.full_like(rows[0], _INF) for _ in range(mp - m)]
    return _bitonic_sort_rows(rows)


def cwmed_kernel(x_ref, o_ref, *, m: int):
    rows = _sorted_rows(x_ref, m)
    if m % 2:
        o_ref[...] = rows[m // 2]
    else:
        o_ref[...] = 0.5 * (rows[m // 2 - 1] + rows[m // 2])


def cwtm_kernel(x_ref, o_ref, *, m: int, trim: int):
    rows = _sorted_rows(x_ref, m)
    keep = rows[trim:m - trim] if trim else rows[:m]
    acc = keep[0]
    for r in keep[1:]:
        acc = acc + r
    o_ref[...] = acc / float(len(keep))


def cwtm_masked_kernel(x_ref, t_ref, o_ref, *, m: int):
    """Trimmed mean with a *data* trim count (the uniform theta path of
    ``core.agg_engine``): same bitonic sort, but the kept band is selected by
    per-row masks against the trim scalar instead of static slicing, so one
    compiled kernel serves every trim value."""
    rows = _sorted_rows(x_ref, m)
    trim = t_ref[0]
    acc = jnp.zeros_like(rows[0])
    for i in range(m):
        keep = jnp.logical_and(i >= trim, i < m - trim)
        acc = acc + jnp.where(keep, rows[i], 0.0)
    o_ref[...] = acc / (m - 2 * trim).astype(jnp.float32)


def _call(kernel, x, tile_d: int, interpret: bool):
    m, d = x.shape
    dp = -(-d // tile_d) * tile_d
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
    out = pl.pallas_call(
        kernel,
        grid=(dp // tile_d,),
        in_specs=[pl.BlockSpec((m, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:d]


def cwmed(x: jax.Array, *, tile_d: int = 2048, interpret: bool = False) -> jax.Array:
    """Coordinate-wise median. x: (m, d) -> (d,) float32."""
    m = x.shape[0]
    return _call(functools.partial(cwmed_kernel, m=m), x, tile_d, interpret)


def cwtm(x: jax.Array, trim: int, *, tile_d: int = 2048,
         interpret: bool = False) -> jax.Array:
    """Coordinate-wise trimmed mean. x: (m, d) -> (d,) float32."""
    m = x.shape[0]
    trim = min(trim, (m - 1) // 2)
    return _call(functools.partial(cwtm_kernel, m=m, trim=trim), x, tile_d, interpret)


def cwtm_masked(x: jax.Array, trim: jax.Array, *, tile_d: int = 2048,
                interpret: bool = False) -> jax.Array:
    """Trimmed mean with a traced trim scalar. x: (m, d) -> (d,) float32.

    ``trim`` rides along as a (1,) int32 operand every grid step reads whole
    (scalars belong in SMEM on real TPUs; a rank-1 int block is the
    interpret-mode-portable equivalent this CPU-validated repo can test)."""
    m, d = x.shape
    trim = jnp.clip(jnp.asarray(trim, jnp.int32), 0, (m - 1) // 2)
    dp = -(-d // tile_d) * tile_d
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
    out = pl.pallas_call(
        functools.partial(cwtm_masked_kernel, m=m),
        grid=(dp // tile_d,),
        in_specs=[pl.BlockSpec((m, tile_d), lambda i: (0, i)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=interpret,
    )(x, trim.reshape(1))
    return out[:d]
