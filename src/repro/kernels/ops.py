"""Jitted public wrappers for the aggregation kernels.

On TPU the Pallas kernels run compiled; on CPU (this container) they run in
``interpret=True`` mode, executing the same kernel bodies for correctness.
These wrappers are the ``pallas`` backend of ``core.agg_engine`` — the three
engine primitives map onto them as

  coordinate-wise reduce      -> ``cwmed_op`` / ``cwtm_op``
  pairwise-distance accumulate-> ``pairwise_sqdist_op`` / ``cross_sqdist_op``
  weighted-combine            -> ``weighted_combine_op``
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import combine as _combine_mod
from repro.kernels import cwmed as _cwmed_mod
from repro.kernels import pairwise as _pairwise_mod


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("tile_d",))
def cwmed_op(x: jax.Array, tile_d: int = 2048) -> jax.Array:
    return _cwmed_mod.cwmed(x, tile_d=tile_d, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("trim", "tile_d"))
def cwtm_op(x: jax.Array, trim: int, tile_d: int = 2048) -> jax.Array:
    return _cwmed_mod.cwtm(x, trim, tile_d=tile_d, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("tile_d",))
def cwtm_masked_op(x: jax.Array, trim: jax.Array, tile_d: int = 2048) -> jax.Array:
    """``cwtm_op`` with the trim count as *data* (traced int32 scalar) — the
    uniform theta path of ``core.agg_engine`` (DESIGN.md §4)."""
    return _cwmed_mod.cwtm_masked(x, trim, tile_d=tile_d, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("tile_d",))
def pairwise_sqdist_op(x: jax.Array, tile_d: int = 4096) -> jax.Array:
    return _pairwise_mod.pairwise_sqdist(x, tile_d=tile_d, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("tile_d",))
def cross_sqdist_op(x: jax.Array, y: jax.Array, tile_d: int = 4096) -> jax.Array:
    return _pairwise_mod.cross_sqdist(x, y, tile_d=tile_d, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("tile_d",))
def weighted_combine_op(x: jax.Array, w: jax.Array, tile_d: int = 2048) -> jax.Array:
    """x: (m, d), w: (k, m) -> (k, d) = w @ x, streamed over d tiles."""
    return _combine_mod.weighted_combine(x, w, tile_d=tile_d, interpret=_interpret())
