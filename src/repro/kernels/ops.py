"""Jitted public wrappers for the aggregation kernels.

On TPU the Pallas kernels run compiled; on CPU (this container) they run in
``interpret=True`` mode, executing the same kernel bodies for correctness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import cwmed as _cwmed_mod
from repro.kernels import pairwise as _pairwise_mod


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("tile_d",))
def cwmed_op(x: jax.Array, tile_d: int = 2048) -> jax.Array:
    return _cwmed_mod.cwmed(x, tile_d=tile_d, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("trim", "tile_d"))
def cwtm_op(x: jax.Array, trim: int, tile_d: int = 2048) -> jax.Array:
    return _cwmed_mod.cwtm(x, trim, tile_d=tile_d, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("tile_d",))
def pairwise_sqdist_op(x: jax.Array, tile_d: int = 4096) -> jax.Array:
    return _pairwise_mod.pairwise_sqdist(x, tile_d=tile_d, interpret=_interpret())
