"""Jitted public wrappers for the aggregation kernels.

On TPU the Pallas kernels run compiled; on CPU (this container) they run in
``interpret=True`` mode, executing the same kernel bodies for correctness.
These wrappers are the ``pallas`` backend of ``core.agg_engine``. Every
single-stage op below is one stage of the fused one-pass kernel
(``fused.py``); ``fused_op`` exposes the multi-stage form — one dispatch,
one HBM read of the (m, d) stack — for composites like NNM's
mix-then-reduce.

  coordinate-wise reduce      -> ``cwmed_op`` / ``cwtm_op`` / ``cwtm_masked_op``
  pairwise-distance accumulate-> ``pairwise_sqdist_op`` / ``cross_sqdist_op``
  weighted-combine            -> ``weighted_combine_op``
  fused multi-stage           -> ``fused_op``
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import fused as _fused_mod


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("tile_d",))
def cwmed_op(x: jax.Array, tile_d: int = 2048) -> jax.Array:
    return _fused_mod.cwmed(x, tile_d=tile_d, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("trim", "tile_d"))
def cwtm_op(x: jax.Array, trim: int, tile_d: int = 2048) -> jax.Array:
    return _fused_mod.cwtm(x, trim, tile_d=tile_d, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("tile_d",))
def cwtm_masked_op(x: jax.Array, trim: jax.Array, tile_d: int = 2048) -> jax.Array:
    """``cwtm_op`` with the trim count as *data* (traced int32 scalar) — the
    uniform theta path of ``core.agg_engine`` (DESIGN.md §4)."""
    return _fused_mod.cwtm_masked(x, trim, tile_d=tile_d, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("tile_d",))
def pairwise_sqdist_op(x: jax.Array, tile_d: int = 4096) -> jax.Array:
    return _fused_mod.pairwise_sqdist(x, tile_d=tile_d, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("tile_d",))
def cross_sqdist_op(x: jax.Array, y: jax.Array, tile_d: int = 4096) -> jax.Array:
    return _fused_mod.cross_sqdist(x, y, tile_d=tile_d, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("tile_d",))
def weighted_combine_op(x: jax.Array, w: jax.Array, tile_d: int = 2048) -> jax.Array:
    """x: (m, d), w: (k, m) -> (k, d) = w @ x, streamed over d tiles."""
    return _fused_mod.weighted_combine(x, w, tile_d=tile_d, interpret=_interpret())


@functools.partial(jax.jit,
                   static_argnames=("reduce", "trim", "pairwise", "combine",
                                    "tile_d"))
def fused_op(x: jax.Array, w: jax.Array | None = None,
             trim_arr: jax.Array | None = None, *, reduce: str | None = None,
             trim: int = 0, pairwise: bool = False, combine: bool = False,
             tile_d: int = 2048) -> dict:
    """Multi-stage fused pass: one dispatch streams the (m, d) stack once and
    returns a dict with any requested subset of ``reduce`` (median /
    trimmed-mean / mean over ``w @ x`` rows, of x rows when w is None),
    ``pairwise`` ((m, m) squared distances of x rows) and ``combine``
    (``w @ x``). Pass a traced trim count via ``trim_arr`` (the static
    ``trim`` is ignored then); a Python trim goes in ``trim``."""
    t = trim_arr if trim_arr is not None else trim
    return _fused_mod.fused_pass(x, w=w, reduce=reduce, trim=t,
                                 pairwise=pairwise, combine=combine,
                                 tile_d=tile_d, interpret=_interpret())
