"""Pure-jnp oracles for the aggregation kernels."""
from __future__ import annotations

import jax.numpy as jnp


def cwmed_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x: (m, d) -> (d,) coordinate-wise median (float32)."""
    return jnp.median(x.astype(jnp.float32), axis=0)


def cwtm_ref(x: jnp.ndarray, trim) -> jnp.ndarray:
    """x: (m, d) -> (d,) trimmed mean dropping `trim` lowest/highest.

    ``trim`` may be a Python int or a traced int32 scalar (the uniform
    theta path of ``core.agg_engine``): one masked sorted-sum form serves
    both, so static and traced calls are bitwise identical by construction
    — a sliced ``xs[trim:m-trim].mean(0)`` would reduce over a different
    tree shape and drift at ULP level."""
    m = x.shape[0]
    xs = jnp.sort(x.astype(jnp.float32), axis=0)
    i = jnp.arange(m)[:, None]
    keep = ((i >= trim) & (i < m - trim)).astype(jnp.float32)
    return (xs * keep).sum(0) / jnp.asarray(m - 2 * trim, jnp.float32)


def pairwise_sqdist_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x: (m, d) -> (m, m) squared L2 distances (float32)."""
    x = x.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return jnp.maximum(d2, 0.0)


def cross_sqdist_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """x: (m, d), y: (k, d) -> (m, k) squared L2 distances (float32).

    Direct subtraction, NOT the ||x||²+||y||²−2x·y expansion: Weiszfeld
    iterates sit close to the points, where the expansion cancels
    catastrophically in f32 (distances ~1e-7·||x||² round to 0 and GeoMed
    degenerates to a mean). k is tiny (1 for GeoMed), so the (m, k, d)
    broadcast is cheap."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    d2 = jnp.sum(jnp.square(x[:, None, :] - y[None, :, :]), axis=-1)
    return jnp.maximum(d2, 0.0)


def weighted_combine_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (m, d), w: (k, m) -> (k, d) = w @ x (float32)."""
    return w.astype(jnp.float32) @ x.astype(jnp.float32)
