"""Pure-jnp oracles for the aggregation kernels."""
from __future__ import annotations

import jax.numpy as jnp


def cwmed_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x: (m, d) -> (d,) coordinate-wise median (float32)."""
    return jnp.median(x.astype(jnp.float32), axis=0)


def cwtm_ref(x: jnp.ndarray, trim: int) -> jnp.ndarray:
    """x: (m, d) -> (d,) trimmed mean dropping `trim` lowest/highest."""
    m = x.shape[0]
    xs = jnp.sort(x.astype(jnp.float32), axis=0)
    if trim == 0:
        return xs.mean(0)
    return xs[trim:m - trim].mean(0)


def pairwise_sqdist_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x: (m, d) -> (m, m) squared L2 distances (float32)."""
    x = x.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return jnp.maximum(d2, 0.0)
