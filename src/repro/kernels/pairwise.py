"""Pairwise / cross squared-distance kernels — the pairwise form is now a
stage of the fused one-pass kernel in ``fused.py`` (cross_sqdist keeps its
own two-operand kernel there for Weiszfeld numerics); this module re-exports
both so existing imports keep working."""
from repro.kernels.fused import cross_sqdist, pairwise_sqdist  # noqa: F401

__all__ = ["pairwise_sqdist", "cross_sqdist"]
