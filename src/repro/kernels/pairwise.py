"""Pallas TPU kernel: m×m pairwise squared distances over a huge feature dim.

Used by the distance-based aggregators (Krum / NNM / MFM / GeoMed init): the
(m, m) Gram/statistics are tiny but the reduction runs over d ~ 1e9+ floats,
so this is a bandwidth-bound streaming reduction. The grid walks d tiles; each
step does an (m, TILE_D) x (TILE_D, m) MXU matmul and accumulates
sq-norm/gram partials straight into the (m, m) output block (output revisited
across the sequential TPU grid => accumulation is safe).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_kernel(x_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (m, tile)
    gram = jax.lax.dot_general(x, x, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (m, m)
    sq = jnp.diagonal(gram)
    part = sq[:, None] + sq[None, :] - 2.0 * gram

    @pl.when(i == 0)
    def _init():
        o_ref[...] = part

    @pl.when(i != 0)
    def _acc():
        o_ref[...] += part


def pairwise_sqdist(x: jax.Array, *, tile_d: int = 4096,
                    interpret: bool = False) -> jax.Array:
    """x: (m, d) -> (m, m) squared L2 distances, f32."""
    m, d = x.shape
    dp = -(-d // tile_d) * tile_d
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
    out = pl.pallas_call(
        _pairwise_kernel,
        grid=(dp // tile_d,),
        in_specs=[pl.BlockSpec((m, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=interpret,
    )(x)
    return jnp.maximum(out, 0.0)


def _cross_kernel(x_ref, y_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (m, tile)
    y = y_ref[...].astype(jnp.float32)  # (k, tile)
    # direct subtraction, not the gram expansion: Weiszfeld iterates sit
    # close to the points and the expansion cancels catastrophically in f32
    # (see cross_sqdist_ref); k is tiny so the (m, k, tile) broadcast fits
    part = jnp.sum(jnp.square(x[:, None, :] - y[None, :, :]), axis=-1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = part

    @pl.when(i != 0)
    def _acc():
        o_ref[...] += part


def cross_sqdist(x: jax.Array, y: jax.Array, *, tile_d: int = 4096,
                 interpret: bool = False) -> jax.Array:
    """x: (m, d), y: (k, d) -> (m, k) squared L2 distances, f32.

    Same streaming reduction as ``pairwise_sqdist`` but between two row sets;
    the aggregation engine uses it for GeoMed's per-iteration distances to the
    Weiszfeld iterate (k = 1)."""
    m, d = x.shape
    k = y.shape[0]
    dp = -(-d // tile_d) * tile_d
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
        y = jnp.pad(y, ((0, 0), (0, dp - d)))
    out = pl.pallas_call(
        _cross_kernel,
        grid=(dp // tile_d,),
        in_specs=[pl.BlockSpec((m, tile_d), lambda i: (0, i)),
                  pl.BlockSpec((k, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
    )(x, y)
    return jnp.maximum(out, 0.0)
