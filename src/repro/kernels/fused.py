"""Pallas TPU kernel: fused one-pass aggregation over tiled (m, d) blocks.

THE kernel of the aggregation engine (DESIGN.md §4): every per-round
primitive — coordinate-wise trim/median selection (formerly ``cwmed.py``),
pairwise-distance accumulation (formerly ``pairwise.py``) and weighted
combine (formerly ``combine.py``) — is a *stage* of one kernel body that
streams each (m, TILE_D) block of the worker stack through VMEM exactly
once. A call requesting several stages pays one HBM read of the stack
instead of one per ``pallas_call``; composites chain stages in-register:
the mix+reduce form (NNM's hot step) multiplies the mixing matrix into the
tile and sorts the *mixed* rows without the (m, d) mixed stack ever
existing in HBM.

Layout (unchanged from the subsumed kernels): m is tiny (9–32 workers),
d is huge, so the grid walks d tiles. Per step:

  * load x: the (m, TILE_D) block, cast to f32 — the single stack read;
  * [pairwise]  (m, TILE_D) × (TILE_D, m) MXU matmul, sq-norm/gram partials
    accumulated straight into the (m, m) output block (output revisited
    across the sequential TPU grid ⇒ accumulation is safe);
  * [mix]       (k, m) × (m, TILE_D) MXU matmul y = w @ x (k ≤ m);
  * [combine]   y written to the (k, TILE_D) output tile;
  * [reduce]    the rows of y (of x when no weights) sorted with a bitonic
    network (min/max row swaps — no data-dependent control flow, VPU
    friendly; the row count padded to a power of two with +inf rows) and
    the median / trimmed mean / mean emitted as a (TILE_D,) tile. The trim
    count is a Python int (statically sliced) or a traced int32 riding
    along as a (1,) operand (per-row masks — one compiled kernel serves
    every trim value; scalars belong in SMEM on real TPUs, a rank-1 int
    block is the interpret-mode-portable equivalent this CPU-validated
    repo can test).

``cross_sqdist`` (GeoMed's Weiszfeld distances) keeps its own two-operand
streaming kernel below: it is the one primitive that cannot share the
stack read (it consumes x *and* the iterate z) and its direct-subtraction
numerics must not go through the gram expansion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_INF = 3.0e38  # python float: becomes a kernel-local constant, not a capture

REDUCE_MODES = ("med", "tm", "mean")


def _bitonic_sort_rows(rows):
    """Sort a list of (TILE_D,) f32 rows ascending, element-wise (each
    coordinate sorted independently across rows). len(rows) must be a power
    of two. Returns the sorted list."""
    n = len(rows)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            for i in range(n):
                l = i ^ j
                if l > i:
                    up = (i & k) == 0
                    a, b = rows[i], rows[l]
                    lo = jnp.minimum(a, b)
                    hi = jnp.maximum(a, b)
                    rows[i] = lo if up else hi
                    rows[l] = hi if up else lo
            j //= 2
        k *= 2
    return rows


def _sorted_rows(rows):
    """Pad a row list to the next power of two with +inf rows (so the
    network is shape-static; statistics index only the valid prefix) and
    sort."""
    n = len(rows)
    np2 = 1 << (n - 1).bit_length()
    rows = list(rows) + [jnp.full_like(rows[0], _INF) for _ in range(np2 - n)]
    return _bitonic_sort_rows(rows)


def _reduce_tile(rows, mode: str, trim, t_ref):
    """Element-wise reduce a list of n f32 rows to one row: ``med`` /
    ``tm`` (static ``trim`` slice, or per-row masks against the traced
    ``t_ref[0]``) / ``mean``. The accumulation orders replicate the
    subsumed cwmed.py kernels exactly, so delegating callers keep their
    numerics."""
    n = len(rows)
    if mode == "mean":
        acc = rows[0]
        for r in rows[1:]:
            acc = acc + r
        return acc / float(n)
    srt = _sorted_rows(rows)
    if mode == "med":
        if n % 2:
            return srt[n // 2]
        return 0.5 * (srt[n // 2 - 1] + srt[n // 2])
    if t_ref is None:  # static trim
        keep = srt[trim:n - trim] if trim else srt[:n]
        acc = keep[0]
        for r in keep[1:]:
            acc = acc + r
        return acc / float(len(keep))
    t = t_ref[0]
    acc = jnp.zeros_like(srt[0])
    for i in range(n):
        live = jnp.logical_and(i >= t, i < n - t)
        acc = acc + jnp.where(live, srt[i], 0.0)
    return acc / (n - 2 * t).astype(jnp.float32)


def _fused_kernel(*refs, m: int, mode, trim: int, has_w: bool, has_t: bool,
                  pairwise: bool, combine: bool):
    it = iter(refs)
    w_ref = next(it) if has_w else None
    x_ref = next(it)
    t_ref = next(it) if has_t else None
    red_ref = next(it) if mode else None
    pw_ref = next(it) if pairwise else None
    comb_ref = next(it) if combine else None

    x = x_ref[...].astype(jnp.float32)  # (m, tile): the ONE stack read

    if pairwise:
        i = pl.program_id(0)
        gram = jax.lax.dot_general(x, x, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        sq = jnp.diagonal(gram)
        part = sq[:, None] + sq[None, :] - 2.0 * gram

        @pl.when(i == 0)
        def _init():
            pw_ref[...] = part

        @pl.when(i != 0)
        def _acc():
            pw_ref[...] += part

    y = x
    if has_w:
        w = w_ref[...].astype(jnp.float32)  # (k, m)
        y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if combine:
            comb_ref[...] = y

    if mode:
        rows = [y[i, :] for i in range(y.shape[0])]
        red_ref[...] = _reduce_tile(rows, mode, trim, t_ref)


def fused_pass(x: jax.Array, *, w=None, reduce=None, trim=0,
               pairwise: bool = False, combine: bool = False,
               tile_d: int = 2048, interpret: bool = False) -> dict:
    """One streaming pass over x: (m, d), producing any requested subset of

      ``reduce``    (d,)   median/trimmed-mean/mean over the rows of
                           ``w @ x`` when ``w`` is given, of x otherwise;
      ``pairwise``  (m, m) squared L2 distances of the rows of x;
      ``combine``   (k, d) ``w @ x`` (requires ``w``: (k, m)).

    ``reduce`` ∈ {"med", "tm", "mean"}; ``trim`` (for "tm") is a Python int
    (statically sliced) or a traced int32 scalar (masked selection), both
    clipped to leave at least one surviving row. Returns a dict keyed by
    the requested stage names. d is padded up to a tile multiple with zero
    columns — inert for every stage (pairwise partials add 0; reduce and
    combine columns beyond d are sliced off).
    """
    if reduce is None and not pairwise and not combine:
        raise ValueError("fused_pass: request at least one of "
                         "reduce/pairwise/combine")
    if reduce is not None and reduce not in REDUCE_MODES:
        raise ValueError(f"unknown reduce mode {reduce!r}; want one of "
                         f"{REDUCE_MODES}")
    if combine and w is None:
        raise ValueError("fused_pass: the combine stage needs weights w")
    m, d = x.shape
    has_w = w is not None
    k = w.shape[0] if has_w else m  # rows entering the reduce stage
    traced_trim = (reduce == "tm"
                   and not isinstance(trim, (int, np.integer)))
    static_trim = 0
    if reduce == "tm" and not traced_trim:
        static_trim = min(int(trim), (k - 1) // 2)
    dp = -(-d // tile_d) * tile_d
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))

    in_specs, args = [], []
    if has_w:
        in_specs.append(pl.BlockSpec((k, m), lambda i: (0, 0)))
        args.append(w.astype(jnp.float32))
    in_specs.append(pl.BlockSpec((m, tile_d), lambda i: (0, i)))
    args.append(x)
    if traced_trim:
        in_specs.append(pl.BlockSpec((1,), lambda i: (0,)))
        args.append(jnp.clip(jnp.asarray(trim, jnp.int32),
                             0, (k - 1) // 2).reshape(1))
    out_specs, out_shapes, keys = [], [], []
    if reduce:
        out_specs.append(pl.BlockSpec((tile_d,), lambda i: (i,)))
        out_shapes.append(jax.ShapeDtypeStruct((dp,), jnp.float32))
        keys.append("reduce")
    if pairwise:
        out_specs.append(pl.BlockSpec((m, m), lambda i: (0, 0)))
        out_shapes.append(jax.ShapeDtypeStruct((m, m), jnp.float32))
        keys.append("pairwise")
    if combine:
        out_specs.append(pl.BlockSpec((k, tile_d), lambda i: (0, i)))
        out_shapes.append(jax.ShapeDtypeStruct((k, dp), jnp.float32))
        keys.append("combine")

    outs = pl.pallas_call(
        functools.partial(_fused_kernel, m=m, mode=reduce, trim=static_trim,
                          has_w=has_w, has_t=traced_trim, pairwise=pairwise,
                          combine=combine),
        grid=(dp // tile_d,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)

    result = {}
    for key, val in zip(keys, outs):
        if key == "reduce":
            result[key] = val[:d]
        elif key == "pairwise":
            result[key] = jnp.maximum(val, 0.0)
        else:
            result[key] = val[:, :d]
    return result


# ------------------------------------------------- single-stage forms
#
# The public functions of the subsumed cwmed.py / pairwise.py / combine.py,
# each now one stage of the fused kernel (same kernel body, same numerics).


def cwmed(x: jax.Array, *, tile_d: int = 2048,
          interpret: bool = False) -> jax.Array:
    """Coordinate-wise median. x: (m, d) -> (d,) float32."""
    return fused_pass(x, reduce="med", tile_d=tile_d,
                      interpret=interpret)["reduce"]


def cwtm(x: jax.Array, trim: int, *, tile_d: int = 2048,
         interpret: bool = False) -> jax.Array:
    """Coordinate-wise trimmed mean. x: (m, d) -> (d,) float32."""
    return fused_pass(x, reduce="tm", trim=int(trim), tile_d=tile_d,
                      interpret=interpret)["reduce"]


def cwtm_masked(x: jax.Array, trim: jax.Array, *, tile_d: int = 2048,
                interpret: bool = False) -> jax.Array:
    """Trimmed mean with a traced trim scalar. x: (m, d) -> (d,) float32."""
    return fused_pass(x, reduce="tm", trim=jnp.asarray(trim, jnp.int32),
                      tile_d=tile_d, interpret=interpret)["reduce"]


def pairwise_sqdist(x: jax.Array, *, tile_d: int = 4096,
                    interpret: bool = False) -> jax.Array:
    """x: (m, d) -> (m, m) squared L2 distances, f32."""
    return fused_pass(x, pairwise=True, tile_d=tile_d,
                      interpret=interpret)["pairwise"]


def weighted_combine(x: jax.Array, w: jax.Array, *, tile_d: int = 2048,
                     interpret: bool = False) -> jax.Array:
    """x: (m, d), w: (k, m) -> (k, d) float32 (``w @ x`` streamed over d)."""
    return fused_pass(x, w=w, combine=True, tile_d=tile_d,
                      interpret=interpret)["combine"]


# ------------------------------------------------- cross distances
#
# GeoMed's Weiszfeld distances: the one primitive outside the fused pass
# (two row sets, and the numerics must avoid the gram expansion).


def _cross_kernel(x_ref, y_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (m, tile)
    y = y_ref[...].astype(jnp.float32)  # (k, tile)
    # direct subtraction, not the gram expansion: Weiszfeld iterates sit
    # close to the points and the expansion cancels catastrophically in f32
    # (see cross_sqdist_ref); k is tiny so the (m, k, tile) broadcast fits
    part = jnp.sum(jnp.square(x[:, None, :] - y[None, :, :]), axis=-1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = part

    @pl.when(i != 0)
    def _acc():
        o_ref[...] += part


def cross_sqdist(x: jax.Array, y: jax.Array, *, tile_d: int = 4096,
                 interpret: bool = False) -> jax.Array:
    """x: (m, d), y: (k, d) -> (m, k) squared L2 distances, f32.

    Same streaming reduction as the pairwise stage but between two row
    sets; the aggregation engine uses it for GeoMed's per-iteration
    distances to the Weiszfeld iterate (k = 1)."""
    m, d = x.shape
    k = y.shape[0]
    dp = -(-d // tile_d) * tile_d
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
        y = jnp.pad(y, ((0, 0), (0, dp - d)))
    out = pl.pallas_call(
        _cross_kernel,
        grid=(dp // tile_d,),
        in_specs=[pl.BlockSpec((m, tile_d), lambda i: (0, i)),
                  pl.BlockSpec((k, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
    )(x, y)
    return jnp.maximum(out, 0.0)
