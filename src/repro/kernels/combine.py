"""Weighted-combine kernel — now the mix stage of the fused one-pass kernel
in ``fused.py``; this module re-exports the single-stage form so existing
imports keep working."""
from repro.kernels.fused import weighted_combine  # noqa: F401

__all__ = ["weighted_combine"]
