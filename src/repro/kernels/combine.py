"""Pallas TPU kernel: fused weighted select/combine ``W @ X`` over d tiles.

One kernel serves every "combine the m worker rows with per-worker weights"
step of the aggregation engine:

  * Krum selection      — W is a (1, m) one-hot (or top-k averaged) row,
  * NNM mixing          — W is the (m, m) nearest-neighbour mixing matrix,
  * MFM filtering       — W is the (1, m) median-filter indicator row,
  * GeoMed/Weiszfeld    — W is the (1, m) inverse-distance weight row,
  * Mean                — W is the uniform (1, m) row.

Layout mirrors ``cwmed.py``: m (and the weight rank k ≤ m) are tiny while d
is huge, so the grid walks d tiles; each step loads an (m, TILE_D) block into
VMEM and performs a (k, m) × (m, TILE_D) MXU matmul straight into the output
tile. The weights are a single (k, m) block revisited by every grid step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(w_ref, x_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)  # (k, m)
    x = x_ref[...].astype(jnp.float32)  # (m, tile)
    o_ref[...] = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)


def weighted_combine(x: jax.Array, w: jax.Array, *, tile_d: int = 2048,
                     interpret: bool = False) -> jax.Array:
    """x: (m, d), w: (k, m) -> (k, d) float32 (``w @ x`` streamed over d)."""
    m, d = x.shape
    k = w.shape[0]
    dp = -(-d // tile_d) * tile_d
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
    out = pl.pallas_call(
        _combine_kernel,
        grid=(dp // tile_d,),
        in_specs=[pl.BlockSpec((k, m), lambda i: (0, 0)),
                  pl.BlockSpec((m, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((k, tile_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k, dp), jnp.float32),
        interpret=interpret,
    )(w.astype(jnp.float32), x)
    return out[:, :d]
