"""The paper's own experiment scale: small classifier used by the
reproduction benchmarks (MNIST/CIFAR-class CNN stand-in as an MLP backbone).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="dynabro-mlp",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=64,
    head_dim=32,
    source="Dorfman et al. 2024, Section 6",
)
