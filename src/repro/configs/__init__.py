"""Architecture configs. One module per assigned architecture.

Each module exposes ``CONFIG`` (a ``ModelConfig``) and the registry maps
``--arch <id>`` to it. ``reduced()`` returns a CPU-smoke-testable variant.
"""
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, reduced

_ARCH_MODULES = {
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "codeqwen1.5-7b": "repro.configs.codeqwen1_5_7b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "arctic-480b": "repro.configs.arctic_480b",
    "smollm-360m": "repro.configs.smollm_360m",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "whisper-base": "repro.configs.whisper_base",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "llama-3.2-vision-90b": "repro.configs.llama_3_2_vision_90b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    "dynabro-mlp": "repro.configs.dynabro_mlp",
}

ARCH_IDS = [a for a in _ARCH_MODULES if a != "dynabro-mlp"]


def get_config(arch_id: str) -> ModelConfig:
    import importlib

    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).CONFIG


def get_reduced_config(arch_id: str, **kw) -> ModelConfig:
    """``reduced(get_config(arch_id), **kw)`` — the model-zoo entry point
    (``models.zoo.make_zoo_task``) and the one-stop smoke-test config."""
    return reduced(get_config(arch_id), **kw)


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCH_IDS", "get_config",
           "get_reduced_config", "reduced"]
