"""SmolLM-360M: small llama-arch dense GQA model.

[hf:HuggingFaceTB/SmolLM-135M family] 32L d_model=960 15H (GQA kv=5)
d_ff=2560 vocab=49152, head_dim=64.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,
    rope_theta=1e4,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
