"""Config dataclasses for architectures and input shapes."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


# The four assigned input shapes.
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    """One architecture. ``family`` selects the block pattern.

    Layer structure is expressed as a repeated *group pattern* so the stack
    lowers as ``lax.scan`` over ``n_layers // group_size`` groups; each entry
    of the pattern is ``(mixer, mlp)`` with
    mixer in {'attn', 'cross_attn', 'mamba', 'rwkv'} and
    mlp in {'dense', 'moe', 'moe+dense', 'rwkv_cmix'}.
    """

    arch_id: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention options ---
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    sliding_window: int = 0  # 0 = full attention; >0 = window size
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    moe_every: int = 1  # MoE MLP on layers where (idx % moe_every == moe_every-1)
    dense_residual: bool = False  # Arctic: parallel dense FFN alongside MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_token_group: int = 4096  # GShard token grouping (0 = single group)

    # --- perf knobs (set by launch/steps.py per mesh) ---
    attn_impl: str = "flash"  # flash | chunked (reference)
    attn_seq_shard: str = ""  # mesh axis to shard the q-seq dim over
    attn_batch_shard: str = ""  # mesh axis the batch dim is sharded over (inference)
    moe_expert_shard: str = ""  # mesh axis for expert parallelism

    # --- hybrid / ssm ---
    attn_every: int = 0  # jamba: 1 attention layer per this many (0 = all attn)
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4
    rwkv_head_dim: int = 64

    # --- enc-dec (audio) ---
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # stubbed frontend: number of frame embeddings

    # --- vlm ---
    cross_attn_every: int = 0  # every k-th layer is cross-attn
    n_image_tokens: int = 0  # stubbed vision tower: patch embeddings

    # --- misc ---
    tie_embeddings: bool = False
    source: str = ""  # citation
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def group_size(self) -> int:
        """Layers per scanned group."""
        if self.family == "hybrid":
            return self.attn_every
        if self.family == "vlm":
            return self.cross_attn_every
        if self.is_moe and self.moe_every > 1:
            return self.moe_every
        return 1

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, (self.arch_id, self.n_layers, self.group_size)
        return self.n_layers // self.group_size

    def pattern(self) -> Tuple[Tuple[str, str], ...]:
        """(mixer, mlp) per layer inside one scanned group."""
        g = self.group_size
        out = []
        for i in range(g):
            if self.family == "ssm":
                out.append(("rwkv", "rwkv_cmix"))
                continue
            if self.family == "hybrid":
                mixer = "attn" if i == g - 1 else "mamba"
            elif self.family == "vlm":
                mixer = "cross_attn" if i == g - 1 else "attn"
            else:
                mixer = "attn"
            if self.is_moe and (i % self.moe_every == self.moe_every - 1):
                mlp = "moe+dense" if self.dense_residual else "moe"
            elif self.is_moe and self.moe_every == 1:
                mlp = "moe+dense" if self.dense_residual else "moe"
            else:
                mlp = "dense"
            out.append((mixer, mlp))
        return tuple(out)

    def supports_shape(self, shape: ShapeConfig) -> bool:
        if shape.kind == "decode" and self.family == "audio" and shape.seq_len > 32_768:
            # whisper: encoder context architecturally capped; skip long_500k.
            return False
        return True

    def for_shape(self, shape: ShapeConfig) -> "ModelConfig":
        """Shape-conditional variant: dense/moe/vlm archs use sliding-window
        self-attention for long-context decode (sub-quadratic requirement)."""
        if (
            shape.kind == "decode"
            and shape.seq_len > 100_000
            and self.family in ("dense", "moe", "vlm")
            and self.sliding_window == 0
        ):
            return dataclasses.replace(self, sliding_window=8192)
        return self

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for roofline."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        n_mlp = 3 if self.act == "swiglu" else 2
        total = v * d * (1 if self.tie_embeddings else 2)
        for mixer, mlp in self.pattern() * self.n_groups:
            if mixer in ("attn", "cross_attn"):
                total += d * hd * (H + 2 * KV) + H * hd * d
            elif mixer == "mamba":
                di = self.mamba_expand * d
                total += d * 2 * di + di * (2 * self.mamba_d_state + 1) + di * d
            elif mixer == "rwkv":
                total += 4 * d * d + 3 * d * d // 8  # r,k,v,o + low-rank decay/mix approx
            if mlp == "dense":
                total += n_mlp * d * ff
            elif mlp in ("moe", "moe+dense"):
                total += self.n_experts * n_mlp * d * ff + d * self.n_experts
                if self.n_shared_experts:
                    total += n_mlp * d * self.shared_d_ff
                if mlp == "moe+dense":
                    total += n_mlp * d * ff
            elif mlp == "rwkv_cmix":
                total += 2 * d * ff + d * d
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (4 * d * hd * H + n_mlp * d * ff)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        n_mlp = 3 if self.act == "swiglu" else 2
        dead = 0
        for _, mlp in self.pattern() * self.n_groups:
            if mlp in ("moe", "moe+dense"):
                dead += (self.n_experts - self.top_k) * n_mlp * d * ff
        return self.param_count() - dead


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (≤4 experts, d≤512)."""
    d_model = min(d_model, 512)
    g = cfg.group_size
    n_layers = max(n_layers, g)
    n_layers = (n_layers // g) * g or g
    hd = 32
    n_heads = max(2, d_model // (2 * hd))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    if cfg.n_kv_heads == cfg.n_heads:  # MHA archs stay MHA
        n_kv = n_heads
    repl = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=hd,
        d_ff=2 * d_model,
        vocab_size=512,
        mamba_d_state=8,
    )
    if cfg.is_moe:
        repl.update(n_experts=4, top_k=min(2, cfg.top_k), shared_d_ff=d_model,
                    n_shared_experts=min(1, cfg.n_shared_experts),
                    capacity_factor=2.0)
    if cfg.n_encoder_layers:
        repl.update(n_encoder_layers=2, encoder_seq=16)
    if cfg.n_image_tokens:
        repl.update(n_image_tokens=16)
    return dataclasses.replace(cfg, **repl)
