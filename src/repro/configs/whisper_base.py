"""Whisper-base: encoder-decoder; mel+conv frontend is a STUB (frame embeddings
are provided directly by input_specs, shape (B, 1500, 512)).

[arXiv:2212.04356] 6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865,
LayerNorm + GELU, learned positions (no RoPE at runtime here).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    n_encoder_layers=6,
    encoder_seq=1500,
    source="arXiv:2212.04356",
)
