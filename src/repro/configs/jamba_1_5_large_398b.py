"""Jamba-1.5-Large: hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887] 72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.
MoE on every other layer (16 experts, top-2); one attention layer per 8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    n_experts=16,
    top_k=2,
    moe_every=2,
    attn_every=8,
    mamba_d_state=16,
    mamba_expand=2,
    source="arXiv:2403.19887",
)
