"""Qwen3-0.6B: dense GQA with qk-norm and explicit head_dim=128.

[hf:Qwen/Qwen3 family] 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B",
)
