"""Snowflake Arctic: 128-expert top-2 MoE + parallel dense residual path.

[hf:Snowflake/snowflake-arctic-base] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    head_dim=128,
    n_experts=128,
    top_k=2,
    dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base",
)
