"""RWKV-6 (Finch) 1.6B: attention-free, data-dependent decay wkv recurrence.

[arXiv:2404.05892] 24L d_model=2048 d_ff=7168 vocab=65536, head_dim=64
(32 wkv heads), O(1) decode state per layer: (H, 64, 64).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_dim=64,
    source="arXiv:2404.05892",
)
