"""Llama-3.2-Vision-90B language backbone: cross-attn image layers every 5th.

[hf:meta-llama/Llama-3.2-11B-Vision] 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256. Vision tower (ViT) is a STUB: input_specs provides
projected patch embeddings (B, 1024, 8192).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    cross_attn_every=5,
    n_image_tokens=1024,
    rope_theta=5e5,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
