from repro.optim.optimizers import (
    Optimizer, sgd, momentum, adam, adagrad_norm, get_optimizer,
)

__all__ = ["Optimizer", "sgd", "momentum", "adam", "adagrad_norm", "get_optimizer"]
