"""Optimizers, including the AdaGrad-Norm rule of Section 5 / Eq. (7):

    η_t = η₀ / sqrt(Σ_{s≤t} ‖g_s‖²)

which adapts to L and (with Option 2's δ-oblivious c_E) to δ.
Minimal optax-like interface: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``; apply with
``apply_updates`` (updates are *subtracted*).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]
    name: str = ""


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) - u).astype(p.dtype),
                        params, updates)


def _global_norm_sq(tree) -> jax.Array:
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(g, state, params=None):
        return jax.tree.map(lambda x: lr * x.astype(jnp.float32), g), state

    return Optimizer(init, update, "sgd")


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    """Heavy-ball momentum (server-side)."""

    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(g, state, params=None):
        m = jax.tree.map(lambda mm, gg: beta * mm + (1 - beta) * gg.astype(jnp.float32),
                         state, g)
        return jax.tree.map(lambda mm: lr * mm, m), m

    return Optimizer(init, update, "momentum")


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.copy, z), "t": jnp.zeros((), jnp.int32)}

    def update(g, state, params=None):
        t = state["t"] + 1
        m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg.astype(jnp.float32),
                         state["m"], g)
        v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * jnp.square(gg.astype(jnp.float32)),
                         state["v"], g)
        mh = jax.tree.map(lambda mm: mm / (1 - b1 ** t.astype(jnp.float32)), m)
        vh = jax.tree.map(lambda vv: vv / (1 - b2 ** t.astype(jnp.float32)), v)
        upd = jax.tree.map(lambda mm, vv: lr * mm / (jnp.sqrt(vv) + eps), mh, vh)
        return upd, {"m": m, "v": v, "t": t}

    return Optimizer(init, update, "adam")


def adagrad_norm(eta0: float) -> Optimizer:
    """AdaGrad-Norm (Eq. 7): single accumulated squared-norm scalar."""

    def init(params):
        return jnp.zeros((), jnp.float32)

    def update(g, acc, params=None):
        acc = acc + _global_norm_sq(g)
        eta = eta0 / jnp.sqrt(jnp.maximum(acc, 1e-12))
        return jax.tree.map(lambda x: eta * x.astype(jnp.float32), g), acc

    return Optimizer(init, update, "adagrad_norm")


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adam": adam,
            "adagrad_norm": adagrad_norm}[name](lr, **kw)
