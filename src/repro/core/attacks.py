"""Byzantine attacks (Appendix J) + the momentum-tailored dynamic attack (App. E).

Every attack maps a stacked honest-gradient tree (leading worker axis m) and a
boolean Byzantine mask (m,) to the attacked stack. Honest statistics (mean,
std) are computed over the honest workers only — the strongest, omniscient
variant used in the paper.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp


def _honest_mean(l, mask):
    w = (~mask).astype(jnp.float32)
    w = w / jnp.maximum(w.sum(), 1.0)
    return jnp.einsum("i,i...->...", w, l.astype(jnp.float32))


def _apply(stacked, mask, fn):
    def leaf(l):
        byz = fn(l)
        mk = mask.reshape((-1,) + (1,) * (l.ndim - 1))
        return jnp.where(mk, byz.astype(l.dtype), l)
    return jax.tree.map(leaf, stacked)


def sign_flip(stacked, mask, key=None, scale: float = 1.0):
    """SF (Allen-Zhu et al., 2020): negate own gradient."""
    return _apply(stacked, mask, lambda l: -scale * l.astype(jnp.float32))


def ipm(stacked, mask, key=None, eps: float = 0.1):
    """Inner-product manipulation (Xie et al., 2020): send −ε · mean(honest)."""
    def leaf(l):
        mu = _honest_mean(l, mask)
        return jnp.broadcast_to(-eps * mu, l.shape)
    return _apply(stacked, mask, leaf)


def alie(stacked, mask, key=None, z: float = 1.22):
    """A Little Is Enough (Baruch et al., 2019): mean − z·std, element-wise."""
    def leaf(l):
        w = (~mask).astype(jnp.float32)
        wn = w / jnp.maximum(w.sum(), 1.0)
        wb = wn.reshape((-1,) + (1,) * (l.ndim - 1))
        mu = (l.astype(jnp.float32) * wb).sum(0)
        var = (jnp.square(l.astype(jnp.float32) - mu) * wb).sum(0)
        return jnp.broadcast_to(mu - z * jnp.sqrt(var + 1e-12), l.shape)
    return _apply(stacked, mask, leaf)


def random_noise(stacked, mask, key, scale: float = 10.0):
    """Gaussian garbage."""
    def leaf_fn(l, k):
        return scale * jax.random.normal(k, l.shape, jnp.float32)
    leaves, treedef = jax.tree.flatten(stacked)
    keys = jax.random.split(key, len(leaves))
    out = []
    for l, k in zip(leaves, keys):
        mk = mask.reshape((-1,) + (1,) * (l.ndim - 1))
        out.append(jnp.where(mk, leaf_fn(l, k).astype(l.dtype), l))
    return jax.tree.unflatten(treedef, out)


def shift(stacked, mask, key=None, v: float = 1.0):
    """Constant-shift attack g + v·1 (used by the App. E dynamic attack)."""
    return _apply(stacked, mask, lambda l: l.astype(jnp.float32) + v)


ATTACKS: Dict[str, Callable] = {
    "none": lambda s, m, key=None, **kw: s,
    "sign_flip": sign_flip,
    "ipm": ipm,
    "alie": alie,
    "random": random_noise,
    "shift": shift,
}


def get_attack(name: str, **kw) -> Callable:
    fn = ATTACKS[name]
    if kw:
        return lambda s, m, key=None: fn(s, m, key=key, **kw)
    return fn


# ----------------------------------------------------- App. E dynamic attack


def momentum_attack_v(t: int, alpha: float, lam: float = 1.0):
    """Attack magnitude v_t of the momentum-tailored dynamic attack (App. E).

    Keeps every worker's momentum biased by ≈ λ despite each worker being
    Byzantine for only 1/(3α) of the time. Returns the scalar multiplier of
    the fixed direction v.
    """
    period = max(int(round(1.0 / alpha)), 3)
    third = max(period // 3, 1)
    tm = t % period
    if t < period:  # first epoch
        if tm in (third, 2 * third):
            return lam / alpha
        return lam
    if tm == 0:  # first round of later epochs (t mod 1/α == 1 in 1-based)
        return lam * (1.0 - (1.0 - alpha) ** (2 * third)) / alpha
    return lam


def momentum_attack_byz_index(t: int, alpha: float, m: int = 3) -> int:
    """Which worker (of 3 groups) is Byzantine at round t under App. E."""
    period = max(int(round(1.0 / alpha)), 3)
    third = max(period // 3, 1)
    return (t % period) // third % 3
