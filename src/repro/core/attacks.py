"""Byzantine attacks (Appendix J) + the momentum-tailored dynamic attack (App. E).

Every attack maps a stacked honest-gradient tree (leading worker axis m) and a
boolean Byzantine mask (m,) to the attacked stack. Honest statistics (mean,
std) are computed over the honest workers only — the strongest, omniscient
variant used in the paper.

Attack parameters (``scale`` / ``eps`` / ``z`` / ``v``) are plain scalar
multipliers inside the leaf math, so every attack works with *traced* scalars
as well as Python floats. The uniform-signature layer at the bottom
(``ATTACK_PARAMS`` / ``attack_theta`` / ``attack_switch``) packages that: the
lane-batched scenario sweep (``core/robust_train.py``) dispatches a per-lane
attack id over a ``lax.switch`` whose branches all share the
``(stacked, mask, key, theta)`` signature, with ``theta`` a parameter vector
carried as data.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _honest_mean(l, mask):
    w = (~mask).astype(jnp.float32)
    w = w / jnp.maximum(w.sum(), 1.0)
    return jnp.einsum("i,i...->...", w, l.astype(jnp.float32))


def _apply(stacked, mask, fn):
    def leaf(l):
        byz = fn(l)
        mk = mask.reshape((-1,) + (1,) * (l.ndim - 1))
        return jnp.where(mk, byz.astype(l.dtype), l)
    return jax.tree.map(leaf, stacked)


def sign_flip(stacked, mask, key=None, scale: float = 1.0):
    """SF (Allen-Zhu et al., 2020): negate own gradient."""
    return _apply(stacked, mask, lambda l: -scale * l.astype(jnp.float32))


def ipm(stacked, mask, key=None, eps: float = 0.1):
    """Inner-product manipulation (Xie et al., 2020): send −ε · mean(honest)."""
    def leaf(l):
        mu = _honest_mean(l, mask)
        return jnp.broadcast_to(-eps * mu, l.shape)
    return _apply(stacked, mask, leaf)


def alie_auto_z(mask) -> jax.Array:
    """The Baruch et al. (2019) z_max, from the Byzantine count in ``mask``.

    With m workers of which b are Byzantine, the attacker needs
    ``s = ⌊m/2 + 1⌋ − b`` honest "supporters" closer to the shifted value
    than to the honest mean; the largest undetected shift is
    ``z = Φ⁻¹((m − b − s) / (m − b))``. Pure jnp, so b may be traced (the
    mask is data in the compiled drivers)."""
    m = mask.shape[0]
    b = jnp.sum(mask.astype(jnp.float32))
    s = jnp.floor(m / 2.0 + 1.0) - b
    good = jnp.maximum(m - b, 1.0)
    frac = (good - s) / good
    return jax.scipy.special.ndtri(
        jnp.clip(frac, 1e-6, 1.0 - 1e-6)).astype(jnp.float32)


def alie(stacked, mask, key=None, z: Optional[float] = 1.22):
    """A Little Is Enough (Baruch et al., 2019): mean − z·std, element-wise.

    ``z=None`` (NaN in the traced ``theta`` path) derives z from (m, n_byz)
    via ``alie_auto_z`` instead of the fixed default; the 1.22 default keeps
    existing goldens untouched."""
    zz = jnp.asarray(jnp.nan if z is None else z, jnp.float32)
    z_eff = jnp.where(jnp.isnan(zz), alie_auto_z(mask), zz)

    def leaf(l):
        w = (~mask).astype(jnp.float32)
        wn = w / jnp.maximum(w.sum(), 1.0)
        wb = wn.reshape((-1,) + (1,) * (l.ndim - 1))
        mu = (l.astype(jnp.float32) * wb).sum(0)
        var = (jnp.square(l.astype(jnp.float32) - mu) * wb).sum(0)
        return jnp.broadcast_to(mu - z_eff * jnp.sqrt(var + 1e-12), l.shape)
    return _apply(stacked, mask, leaf)


def random_noise(stacked, mask, key, scale: float = 10.0):
    """Gaussian garbage."""
    def leaf_fn(l, k):
        return scale * jax.random.normal(k, l.shape, jnp.float32)
    leaves, treedef = jax.tree.flatten(stacked)
    keys = jax.random.split(key, len(leaves))
    out = []
    for l, k in zip(leaves, keys):
        mk = mask.reshape((-1,) + (1,) * (l.ndim - 1))
        out.append(jnp.where(mk, leaf_fn(l, k).astype(l.dtype), l))
    return jax.tree.unflatten(treedef, out)


def shift(stacked, mask, key=None, v: float = 1.0):
    """Constant-shift attack g + v·1 (used by the App. E dynamic attack)."""
    return _apply(stacked, mask, lambda l: l.astype(jnp.float32) + v)


ATTACKS: Dict[str, Callable] = {
    "none": lambda s, m, key=None, **kw: s,
    "sign_flip": sign_flip,
    "ipm": ipm,
    "alie": alie,
    "random": random_noise,
    "shift": shift,
}


def get_attack(name: str, **kw) -> Callable:
    fn = ATTACKS[name]
    if kw:
        return lambda s, m, key=None: fn(s, m, key=key, **kw)
    return fn


# ----------------------------------------- uniform traced-theta dispatch
#
# The lane-batched sweep (``run_dynabro_scan_sweep``) runs cells with
# *different* attacks as lanes of one vmapped scan, so the attack choice and
# its parameters must be data, not Python closure constants. Slot i of a
# lane's ``theta`` vector holds the i-th parameter of its attack per
# ``ATTACK_PARAMS`` (NaN in alie's z slot encodes ``z=None`` → derive z from
# the mask); ``attack_switch(names)`` builds the ``lax.switch`` applier over
# the compact branch set actually present in the sweep.

ATTACK_PARAMS: Dict[str, Tuple[Tuple[str, float], ...]] = {
    "none": (),
    "sign_flip": (("scale", 1.0),),
    "ipm": (("eps", 0.1),),
    "alie": (("z", 1.22),),
    "random": (("scale", 10.0),),
    "shift": (("v", 1.0),),
}
N_PARAMS = max(len(spec) for spec in ATTACK_PARAMS.values())

# parameters that accept None (encoded as NaN in theta AND interpreted by
# the attack); None for any other parameter would silently turn into NaN
# gradients on the lane path while the eager kwarg path raises — reject it
NAN_SENTINEL_PARAMS = {("alie", "z")}


def attack_theta(name: str,
                 kwargs: Optional[Mapping[str, Any]] = None) -> np.ndarray:
    """(N_PARAMS,) float32 parameter vector for ``name`` — the per-lane row
    of the sweep's (C, N_PARAMS) parameter matrix. Unset parameters take
    their ``ATTACK_PARAMS`` defaults; unknown ones raise, as does ``None``
    for a parameter without NaN-sentinel support."""
    kw = dict(kwargs or {})
    theta = np.zeros(N_PARAMS, np.float32)
    for i, (pname, default) in enumerate(ATTACK_PARAMS[name]):
        val = kw.pop(pname, default)
        if val is None and (name, pname) not in NAN_SENTINEL_PARAMS:
            raise TypeError(
                f"{name!r} attack parameter {pname!r} does not accept None")
        theta[i] = np.nan if val is None else float(val)
    if kw:
        raise TypeError(f"unknown {name!r} attack parameter(s): {sorted(kw)}")
    return theta


def uniform_attack(name: str) -> Callable:
    """``name`` under the uniform ``(stacked, mask, key, theta)`` signature —
    the ``lax.switch`` branch form, reading parameters from theta slots."""
    fn = ATTACKS[name]
    spec = ATTACK_PARAMS[name]

    def call(stacked, mask, key, theta):
        kw = {pname: theta[i] for i, (pname, _) in enumerate(spec)}
        return fn(stacked, mask, key=key, **kw)

    return call


def attack_switch(names: Sequence[str]) -> Callable:
    """``apply(idx, stacked, mask, key, theta)`` dispatching ``lax.switch``
    over the uniform implementations of ``names`` (``idx`` indexes into
    ``names``). Under ``vmap`` with a lane-mapped idx this lowers to
    execute-all-branches-and-select — cheap, since attacks are O(m·d) next
    to the per-worker gradient work. A single name skips the switch."""
    branches = tuple(uniform_attack(n) for n in names)
    if len(branches) == 1:
        only = branches[0]
        return lambda idx, stacked, mask, key, theta: only(
            stacked, mask, key, theta)

    def apply(idx, stacked, mask, key, theta):
        return jax.lax.switch(idx, [lambda op, b=b: b(*op) for b in branches],
                              (stacked, mask, key, theta))

    return apply


# ----------------------------------------------------- App. E dynamic attack


def momentum_attack_v(t: int, alpha: float, lam: float = 1.0):
    """Attack magnitude v_t of the momentum-tailored dynamic attack (App. E).

    Keeps every worker's momentum biased by ≈ λ despite each worker being
    Byzantine for only 1/(3α) of the time. Returns the scalar multiplier of
    the fixed direction v.
    """
    period = max(int(round(1.0 / alpha)), 3)
    third = max(period // 3, 1)
    tm = t % period
    if t < period:  # first epoch
        if tm in (third, 2 * third):
            return lam / alpha
        return lam
    if tm == 0:  # first round of later epochs (t mod 1/α == 1 in 1-based)
        return lam * (1.0 - (1.0 - alpha) ** (2 * third)) / alpha
    return lam


def momentum_attack_byz_index(t: int, alpha: float, m: int = 3) -> int:
    """Which worker (of 3 groups) is Byzantine at round t under App. E."""
    period = max(int(round(1.0 / alpha)), 3)
    third = max(period // 3, 1)
    return (t % period) // third % 3
