"""Mode A — paper-faithful DynaBRO training (Algorithms 1 & 2) + baselines.

Workers are simulated with ``vmap`` (exactly the paper's experimental setup):
per round t, each of the m workers computes ``2^{J_t}`` unit-batch gradients;
Byzantine workers (per the switching strategy, possibly changing *within* the
round) corrupt theirs; the server aggregates levels 0, J−1, J with a robust
rule, applies the MLMC combine + fail-safe filter, and takes an SGD /
AdaGrad-Norm step.

Baselines: worker-momentum (Karimireddy et al., 2021) and vanilla SGD —
robust aggregation of worker momentums / gradients.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attacks as attacks_lib
from repro.core.aggregators import Aggregator, MFM, get_aggregator
from repro.core.mlmc import MLMCConfig, mlmc_combine, sample_level
from repro.core.switching import Switcher
from repro.optim.optimizers import Optimizer, apply_updates

GradFn = Callable[[Any, Any], Any]  # (params, unit_batch) -> grad tree


@dataclasses.dataclass
class DynaBROConfig:
    mlmc: MLMCConfig
    aggregator: str = "cwtm"  # any core.agg_engine registry rule
    delta: float = 0.25
    attack: str = "sign_flip"
    attack_kwargs: Optional[dict] = None
    use_mlmc: bool = True  # False -> plain robust-aggregated SGD
    agg_backend: str = "auto"  # engine backend: ref | pallas | auto


def _per_worker_grads(grad_fn: GradFn, params, batches):
    """batches: tree leading (m, n, ...) -> grads tree leading (m, n, ...)."""
    g1 = jax.vmap(grad_fn, in_axes=(None, 0))
    return jax.vmap(g1, in_axes=(None, 0))(params, batches)


def _attack_stack(cfg: DynaBROConfig, grads, masks, key):
    """grads: (m, n, ...) leaves; masks: (n, m) bool -> attacked grads."""
    atk = attacks_lib.get_attack(cfg.attack, **(cfg.attack_kwargs or {}))
    swapped = jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), grads)  # (n, m, ...)
    keys = jax.random.split(key, masks.shape[0])
    attacked = jax.vmap(lambda s, mk, k: atk(s, mk, key=k))(swapped, masks, keys)
    return jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), attacked)  # (m, n, ...)


def _aggregate(cfg: DynaBROConfig, stacked, n: int):
    """Robustly aggregate a worker-stacked tree; MFM threshold scales 1/√n."""
    if cfg.aggregator == "mfm":
        agg = MFM(backend=cfg.agg_backend)
        return agg.tree(stacked, tau=cfg.mlmc.mfm_tau(n))
    agg = get_aggregator(cfg.aggregator, delta=cfg.delta, backend=cfg.agg_backend)
    return agg.tree(stacked)


def make_dynabro_step(grad_fn: GradFn, cfg: DynaBROConfig, opt: Optimizer):
    """Returns step(params, opt_state, batches, masks, key, j) jitted per level.

    batches: tree leading (m, 2^j) (or (m, 1) when j=0 / beyond cap);
    masks: (2^j, m) bool — within-round identity masks.
    """

    @functools.partial(jax.jit, static_argnames=("j",))
    def step(params, opt_state, batches, masks, key, j: int):
        grads = _per_worker_grads(grad_fn, params, batches)  # (m, n, ...)
        grads = _attack_stack(cfg, grads, masks, key)
        n = masks.shape[0]
        gbar_all = jax.tree.map(lambda l: l.mean(1), grads)  # level j: mean of n
        g0_stack = jax.tree.map(lambda l: l[:, 0], grads)  # level 0: first sample
        g0 = _aggregate(cfg, g0_stack, 1)
        if cfg.use_mlmc and j >= 1 and j <= cfg.mlmc.j_max:
            gh = jax.tree.map(lambda l: l[:, : n // 2].mean(1), grads)
            gjm1 = _aggregate(cfg, gh, n // 2)
            gj = _aggregate(cfg, gbar_all, n)
            g, info = mlmc_combine(g0, gjm1, gj, j, cfg.mlmc)
        else:
            g, info = mlmc_combine(g0, None, None, cfg.mlmc.j_max + 1, cfg.mlmc)
            if not cfg.use_mlmc:  # plain robust SGD on the full mini-batch
                g = _aggregate(cfg, gbar_all, n)
        updates, opt_state = opt.update(g, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, info

    return step


def make_momentum_step(grad_fn: GradFn, cfg: DynaBROConfig, lr: float, beta: float):
    """Worker-momentum baseline: attack on gradients feeding each worker's
    momentum recursion (App. E semantics); server robustly aggregates
    momentums. beta=0 recovers vanilla distributed SGD."""

    @jax.jit
    def step(params, worker_m, batches, mask, key):
        # batches: tree leading (m,) unit batches; mask: (m,)
        grads = jax.vmap(grad_fn, in_axes=(None, 0))(params, batches)
        grads = attacks_lib.get_attack(cfg.attack, **(cfg.attack_kwargs or {}))(
            grads, mask, key=key)
        worker_m = jax.tree.map(
            lambda mm, gg: beta * mm + (1.0 - beta) * gg.astype(jnp.float32),
            worker_m, grads)
        agg = _aggregate(cfg, worker_m, 1)
        params = apply_updates(params, jax.tree.map(lambda x: lr * x, agg))
        return params, worker_m

    return step


# -------------------------------------------------------------- driver


@dataclasses.dataclass
class RoundLog:
    level: int
    failsafe_ok: bool
    n_byz: int
    cost: int


def run_dynabro(
    grad_fn: GradFn,
    params,
    opt: Optimizer,
    cfg: DynaBROConfig,
    switcher: Switcher,
    sample_batches: Callable[[int, int], Any],  # (t, n) -> tree leading (m, n)
    T: int,
    seed: int = 0,
    eval_fn: Optional[Callable[[Any, int], Dict[str, float]]] = None,
    eval_every: int = 0,
):
    """Run Algorithm 2 for T rounds. Returns (params, logs, evals)."""
    rng = np.random.default_rng(seed)
    step = make_dynabro_step(grad_fn, cfg, opt)
    opt_state = opt.init(params)
    logs, evals = [], []
    for t in range(T):
        j = sample_level(rng, cfg.mlmc.j_max) if cfg.use_mlmc else 0
        n = 2 ** j if (cfg.use_mlmc and j <= cfg.mlmc.j_max) else 1
        masks = np.stack([switcher.within_round(t, k) for k in range(n)])
        batches = sample_batches(t, n)
        key = jax.random.PRNGKey(seed * 100_003 + t)
        params, opt_state, info = step(params, opt_state, batches,
                                       jnp.asarray(masks), key, j)
        logs.append(RoundLog(j, bool(info["failsafe_ok"]), int(masks[0].sum()),
                             1 + (n + n // 2 if j >= 1 else 0)))
        if eval_fn and eval_every and (t + 1) % eval_every == 0:
            evals.append((t + 1, eval_fn(params, t)))
    return params, logs, evals


def run_momentum(
    grad_fn: GradFn,
    params,
    cfg: DynaBROConfig,
    switcher: Switcher,
    sample_batches: Callable[[int, int], Any],
    T: int,
    lr: float,
    beta: float,
    seed: int = 0,
    eval_fn: Optional[Callable[[Any, int], Dict[str, float]]] = None,
    eval_every: int = 0,
):
    """Worker-momentum / vanilla-SGD baseline driver (same budget accounting
    is done by the caller: one unit batch per worker per round)."""
    step = make_momentum_step(grad_fn, cfg, lr, beta)
    worker_m = jax.tree.map(
        lambda p: jnp.zeros((switcher.m,) + p.shape, jnp.float32), params)
    evals = []
    for t in range(T):
        mask = switcher.mask(t)
        batches = jax.tree.map(lambda l: l[:, 0], sample_batches(t, 1))
        key = jax.random.PRNGKey(seed * 77_003 + t)
        params, worker_m = step(params, worker_m, batches, jnp.asarray(mask), key)
        if eval_fn and eval_every and (t + 1) % eval_every == 0:
            evals.append((t + 1, eval_fn(params, t)))
    return params, evals
