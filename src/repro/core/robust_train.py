"""Mode A — paper-faithful DynaBRO training (Algorithms 1 & 2) + baselines.

Workers are simulated with ``vmap`` (exactly the paper's experimental setup):
per round t, each of the m workers computes ``2^{J_t}`` unit-batch gradients;
Byzantine workers (per the switching strategy, possibly changing *within* the
round) corrupt theirs; the server aggregates levels 0, J−1, J with a robust
rule, applies the MLMC combine + fail-safe filter, and takes an SGD /
AdaGrad-Norm step.

Baselines: worker-momentum (Karimireddy et al., 2021) and vanilla SGD —
robust aggregation of worker momentums / gradients.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Any, Callable, Dict, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agg_engine
from repro.core import attacks as attacks_lib
from repro.core.aggregators import MFM, get_aggregator
from repro.core.mlmc import (
    MLMCConfig, level_prefix, level_schedule, mlmc_combine, round_cost,
)
from repro.core.switching import Switcher
from repro.optim.optimizers import Optimizer, apply_updates

GradFn = Callable[[Any, Any], Any]  # (params, unit_batch) -> grad tree


@dataclasses.dataclass
class DynaBROConfig:
    mlmc: MLMCConfig
    aggregator: str = "cwtm"  # any core.agg_engine registry rule
    delta: float = 0.25
    attack: str = "sign_flip"
    attack_kwargs: Optional[dict] = None
    use_mlmc: bool = True  # False -> plain robust-aggregated SGD
    agg_backend: str = "auto"  # engine backend: ref | pallas | auto
    # extra rule hyperparameters (Krum's multi, GeoMed's iters/eps, MFM's
    # tau, or a delta overriding the field above) — the per-cell mirror of
    # the sweep's per-lane agg theta (DESIGN.md §4)
    aggregator_kwargs: Optional[dict] = None


def _per_worker_grads(grad_fn: GradFn, params, batches):
    """batches: tree leading (m, n, ...) -> grads tree leading (m, n, ...)."""
    g1 = jax.vmap(grad_fn, in_axes=(None, 0))
    return jax.vmap(g1, in_axes=(None, 0))(params, batches)


def _attack_stack(cfg: DynaBROConfig, grads, masks, key, lane_attack=None):
    """grads: (m, n, ...) leaves; masks: (n, m) bool -> attacked grads.

    The per-computation key is ``fold_in(key, k)`` — a function of the
    within-round index k alone, so the k-th computation draws the same key
    whether the round runs at its exact batch size (legacy driver) or as the
    prefix of an n_max-padded batch (scan driver).

    ``lane_attack`` (an ``(apply, attack_id, theta)`` triple, with ``apply``
    from ``attacks.attack_switch``) routes through the traced per-lane attack
    dispatch of the lane-batched sweep instead of the cfg-static attack.
    """
    if lane_attack is None:
        atk0 = attacks_lib.get_attack(cfg.attack, **(cfg.attack_kwargs or {}))

        def atk(s, mk, k):
            return atk0(s, mk, key=k)
    else:
        apply_fn, attack_id, theta = lane_attack

        def atk(s, mk, k):
            return apply_fn(attack_id, s, mk, k, theta)
    swapped = jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), grads)  # (n, m, ...)
    keys = jax.vmap(lambda k: jax.random.fold_in(key, k))(
        jnp.arange(masks.shape[0]))
    attacked = jax.vmap(atk)(swapped, masks, keys)
    return jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), attacked)  # (m, n, ...)


def _aggregate(cfg: DynaBROConfig, stacked, n: int, lane_agg=None):
    """Robustly aggregate a worker-stacked tree; MFM threshold scales 1/√n.

    ``lane_agg`` (an ``(apply, agg_id, theta)`` triple, with ``apply`` from
    ``agg_engine.agg_switch``) routes through the traced per-lane rule
    dispatch of the lane-batched sweep instead of the cfg-static rule."""
    if lane_agg is not None:
        apply_fn, agg_id, theta = lane_agg
        return apply_fn(agg_id, stacked, n, theta)
    kw = dict(cfg.aggregator_kwargs or {})
    delta = kw.pop("delta", cfg.delta)
    if cfg.aggregator == "mfm":
        tau = kw.pop("tau", None)
        agg = MFM(backend=cfg.agg_backend, **kw)
        return agg.tree(stacked, tau=cfg.mlmc.mfm_tau(n) if tau is None else tau)
    agg = get_aggregator(cfg.aggregator, delta=delta, backend=cfg.agg_backend,
                         **kw)
    return agg.tree(stacked)


def _combine_from_levels(cfg: DynaBROConfig, g0_stack, gh, gbar_all, n: int,
                         j: int, lane_agg=None, lane_thr=None):
    """Aggregate the per-worker level means and apply the MLMC combine — the
    shared tail of ``_combine_levels`` (which feeds it slices of the stacked
    (m, n, ...) grads) and the microbatched scan branches (which feed it
    streamed accumulator means, DESIGN.md §9). g0_stack / gh / gbar_all are
    (m, ...) trees: each worker's level-0 unit, first-half mean and full
    mean; ``gh`` may be None whenever the MLMC branch below is dead.
    ``lane_thr`` is the per-lane fail-safe coefficient (1+√2)·c_E·C·V of the
    aggregator-lane sweep — c_E depends on the lane's rule (MFM is Option
    2), so it travels as data next to the lane's (agg_id, theta)."""
    if cfg.use_mlmc and j >= 1 and j <= cfg.mlmc.j_max:
        if lane_agg is not None:
            # all three levels through ONE rule dispatch: under vmap the
            # agg_switch select executes every branch per lane, so paying it
            # once per round instead of once per level is most of the
            # aggregator-lane sweep's win (DESIGN.md §7); the per-level
            # numerics are the exact scalar-n calls (agg_engine._per_level)
            apply_fn, agg_id, theta = lane_agg
            stacked = jax.tree.map(lambda a, b, c: jnp.stack([a, b, c]),
                                   g0_stack, gh, gbar_all)
            out = apply_fn(agg_id, stacked, (1, n // 2, n), theta)
            g0, gjm1, gj = (jax.tree.map(lambda l, i=i: l[i], out)
                            for i in range(3))
        else:
            g0 = _aggregate(cfg, g0_stack, 1)
            gjm1 = _aggregate(cfg, gh, n // 2)
            gj = _aggregate(cfg, gbar_all, n)
        thr = None if lane_thr is None else lane_thr / jnp.sqrt(2.0 ** j)
        return mlmc_combine(g0, gjm1, gj, j, cfg.mlmc, threshold=thr)
    g0 = _aggregate(cfg, g0_stack, 1, lane_agg)
    g, info = mlmc_combine(g0, None, None, cfg.mlmc.j_max + 1, cfg.mlmc)
    if not cfg.use_mlmc:  # plain robust SGD on the full mini-batch
        g = _aggregate(cfg, gbar_all, n, lane_agg)
    return g, info


def _combine_levels(cfg: DynaBROConfig, grads, j: int, lane_agg=None,
                    lane_thr=None):
    """Slice the attacked (m, n, ...) stack into the three level means and
    combine — the one round body shared by the per-level jitted step and
    every non-microbatched ``lax.switch`` branch of the scan driver, so the
    two cannot diverge. ``j`` and the leaf batch size n are static."""
    n = jax.tree.leaves(grads)[0].shape[1]
    gbar_all = jax.tree.map(lambda l: l.mean(1), grads)  # level j: mean of n
    g0_stack = jax.tree.map(lambda l: l[:, 0], grads)  # level 0: first sample
    gh = None
    if cfg.use_mlmc and j >= 1 and j <= cfg.mlmc.j_max:
        gh = jax.tree.map(lambda l: l[:, : n // 2].mean(1), grads)
    return _combine_from_levels(cfg, g0_stack, gh, gbar_all, n, j,
                                lane_agg=lane_agg, lane_thr=lane_thr)


def make_dynabro_step(grad_fn: GradFn, cfg: DynaBROConfig, opt: Optimizer):
    """Returns step(params, opt_state, batches, masks, key, j) jitted per level.

    batches: tree leading (m, 2^j) (or (m, 1) when j=0 / beyond cap);
    masks: (2^j, m) bool — within-round identity masks.
    """

    @functools.partial(jax.jit, static_argnames=("j",))
    def step(params, opt_state, batches, masks, key, j: int):
        grads = _per_worker_grads(grad_fn, params, batches)  # (m, n, ...)
        grads = _attack_stack(cfg, grads, masks, key)
        g, info = _combine_levels(cfg, grads, j)
        updates, opt_state = opt.update(g, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, info

    return step


def _make_momentum_round(grad_fn: GradFn, cfg: DynaBROConfig, lr: float,
                         beta: float, gather=None):
    """One worker-momentum round — shared by the jitted per-round step and
    the scan driver's body, so the two cannot diverge. ``gather`` re-assembles
    device-local worker slices into the full (m, ...) stack in the sharded
    driver (DESIGN.md §7); None on the single-device paths."""
    atk = attacks_lib.get_attack(cfg.attack, **(cfg.attack_kwargs or {}))

    def round_fn(params, worker_m, batches, mask, key):
        # batches: tree leading (m[_local],) unit batches; mask: (m,)
        grads = jax.vmap(grad_fn, in_axes=(None, 0))(params, batches)
        if gather is not None:
            grads = gather(grads)
        grads = atk(grads, mask, key=key)
        worker_m = jax.tree.map(
            lambda mm, gg: beta * mm + (1.0 - beta) * gg.astype(jnp.float32),
            worker_m, grads)
        agg = _aggregate(cfg, worker_m, 1)
        params = apply_updates(params, jax.tree.map(lambda x: lr * x, agg))
        return params, worker_m

    return round_fn


def make_momentum_step(grad_fn: GradFn, cfg: DynaBROConfig, lr: float, beta: float):
    """Worker-momentum baseline: attack on gradients feeding each worker's
    momentum recursion (App. E semantics); server robustly aggregates
    momentums. beta=0 recovers vanilla distributed SGD."""
    return jax.jit(_make_momentum_round(grad_fn, cfg, lr, beta))


# -------------------------------------------------------------- driver


@dataclasses.dataclass
class RoundLog:
    level: int
    failsafe_ok: bool
    n_byz: int
    cost: int


def run_dynabro(
    grad_fn: GradFn,
    params,
    opt: Optimizer,
    cfg: DynaBROConfig,
    switcher: Switcher,
    sample_batches: Callable[[int, int], Any],  # (t, n) -> tree leading (m, n)
    T: int,
    seed: int = 0,
    eval_fn: Optional[Callable[[Any, int], Dict[str, float]]] = None,
    eval_every: int = 0,
    step=None,
):
    """Run Algorithm 2 for T rounds. Returns (params, logs, evals).

    Reference Python-loop implementation — one compiled step dispatch per
    round; ``run_dynabro_scan`` is the compiled equivalent the parity suite
    checks against this. Pass a prebuilt ``step`` (from ``make_dynabro_step``)
    to reuse its jit cache across runs.

    Thin wrapper over ``repro.api.Session`` (DESIGN.md §10)."""
    from repro.api.session import Session
    sess = Session(cfg, grad_fn=grad_fn, params0=params, opt=opt,
                   switcher=switcher, sample_batches=sample_batches,
                   seed=seed)
    return sess.run(T, eval_fn=eval_fn, eval_every=eval_every,
                    driver="legacy", step=step)


def run_momentum(
    grad_fn: GradFn,
    params,
    cfg: DynaBROConfig,
    switcher: Switcher,
    sample_batches: Callable[[int, int], Any],
    T: int,
    lr: float,
    beta: float,
    seed: int = 0,
    eval_fn: Optional[Callable[[Any, int], Dict[str, float]]] = None,
    eval_every: int = 0,
    step=None,
):
    """Worker-momentum / vanilla-SGD baseline driver (same budget accounting
    is done by the caller: one unit batch per worker per round).

    Thin wrapper over ``repro.api.Session`` (DESIGN.md §10)."""
    from repro.api.session import Session
    sess = Session(cfg, grad_fn=grad_fn, params0=params, mode="momentum",
                   lr=lr, beta=beta, switcher=switcher,
                   sample_batches=sample_batches, seed=seed)
    return sess.run(T, eval_fn=eval_fn, eval_every=eval_every,
                    driver="legacy", step=step)


# ----------------------------------------------- compiled (lax.scan) drivers
#
# The Python-loop drivers above dispatch one compiled step per round and
# rebuild masks/batches on the host — O(T) dispatch overhead. The scan
# drivers precompute the full round schedule host-side (seeded identically,
# so they are round-for-round equivalent) and run the whole loop inside
# chunked ``lax.scan`` segments. DESIGN.md §5.


def _np_prng_keys(seeds) -> np.ndarray:
    """(T, 2) uint32 raw keys, entry i == ``jax.random.PRNGKey(seeds[i])``.

    Built with numpy (threefry seed layout: [s >> 32, s & 0xffffffff]) to
    avoid T per-round host->device dispatches; a probe key is checked against
    the runtime and on mismatch (non-default PRNG impl) we fall back to the
    per-seed PRNGKey loop.
    """
    seeds = np.asarray(seeds, np.int64)
    keys = np.stack([(seeds >> 32).astype(np.uint32),
                     (seeds & np.int64(0xFFFFFFFF)).astype(np.uint32)], -1)
    probe = np.asarray(jax.random.PRNGKey(int(seeds[0])))
    if probe.shape == keys[0].shape and (probe == keys[0]).all():
        return keys
    return np.stack([np.asarray(jax.random.PRNGKey(int(s))) for s in seeds])


def _pad_units(tree, n_max: int, axis: int):
    """Pad the within-round unit axis to n_max by repeating the first unit
    (branch j only ever reads the first 2^j units, so pad values are inert)."""
    def pad(l):
        n = l.shape[axis]
        if n == n_max:
            return l
        idx = [slice(None)] * l.ndim
        idx[axis] = slice(0, 1)
        reps = list(l.shape)
        reps[axis] = n_max - n
        return jnp.concatenate(
            [l, jnp.broadcast_to(l[tuple(idx)], tuple(reps))], axis=axis)
    return jax.tree.map(pad, tree)


def _batch_schedule(sample_batches, tn, n_max: int, vectorize: bool = True):
    """Stack per-round batches into an (L, m, n_max, ...) padded schedule.

    ``tn`` is the segment's [(t, n_t), ...]; each round calls
    ``sample_batches(t, n_t)`` at the exact per-round batch size of the legacy
    driver (the sampler's output may depend on n, so padding must happen
    *after* sampling to preserve parity). Rounds are grouped by level and the
    sampler is vmapped over t, so host-side cost is O(#levels) dispatches
    instead of O(T); a probe round is compared against the direct call and any
    sampler that is not traceable in t — or ignores a traced t — falls back to
    the per-round loop.

    The vectorized path requires the sampler to be a pure function of (t, n):
    the vmap trace and the probe each invoke it extra times, which would
    advance any hidden per-call state before the fallback replays the rounds.
    Such samplers must run with ``vectorize=False`` — the per-round loop
    calls the sampler exactly once per round, in round order, like the legacy
    driver.
    """
    if vectorize:
        try:
            groups: Dict[int, list] = {}
            for i, (t, n) in enumerate(tn):
                groups.setdefault(int(n), []).append((i, int(t)))
            out = None
            for n, its in sorted(groups.items()):
                idx = jnp.asarray(np.array([i for i, _ in its], np.int32))
                ts = jnp.asarray(np.array([t for _, t in its], np.int32))
                bt = jax.vmap(lambda t: sample_batches(t, n))(ts)
                bt = _pad_units(bt, n_max, axis=2)
                if out is None:
                    out = jax.tree.map(
                        lambda l: jnp.zeros((len(tn),) + l.shape[1:], l.dtype),
                        bt)
                out = jax.tree.map(lambda o, l: o.at[idx].set(l), out, bt)
            n_probe, its_probe = max(groups.items(), key=lambda kv: len(kv[1]))
            i0, t0 = its_probe[-1]
            want = _pad_units(sample_batches(t0, n_probe), n_max, axis=1)
            got = jax.tree.map(lambda l: l[i0], out)
            if not all(bool(jnp.array_equal(a, b)) for a, b in
                       zip(jax.tree.leaves(got), jax.tree.leaves(want))):
                raise ValueError("vectorized sampler disagrees with direct call")
            return out
        except (TypeError, ValueError) as e:
            # TypeError: sampler not traceable in t (jax tracer-leak errors
            # subclass it); ValueError: probe mismatch / host-side shape
            # complaints. Anything else (OOM, internal bugs) propagates —
            # silently reverting to O(T) dispatch would mask it.
            warnings.warn(
                f"run_*_scan: per-round batch sampling fallback ({e}); pass "
                "vectorize_batches=False to silence", RuntimeWarning)
    rows = [_pad_units(sample_batches(t, int(n)), n_max, axis=1)
            for t, n in tn]
    return jax.tree.map(lambda *ls: jnp.stack(ls), *rows)


def _level_plan(cfg: DynaBROConfig, rng: np.random.Generator, T: int):
    """Host-side MLMC level plan: (levels (T,), per-round unit counts ns,
    n_max) — replaying the exact level stream the legacy driver draws.
    Shared by ``run_dynabro_scan`` and the vmapped sweep, which must agree
    round for round."""
    j_max = cfg.mlmc.j_max
    if cfg.use_mlmc:
        levels = level_schedule(rng, j_max, T)
        n_max = 2 ** j_max
        ns = np.where(levels <= j_max, 2 ** levels.astype(np.int64), 1)
    else:
        levels = np.zeros(T, np.int32)
        n_max = 1
        ns = np.ones(T, np.int64)
    return levels, ns, n_max


def _round_logs(levels, ok, masks, j_max: int) -> list:
    """Per-round RoundLog list from the level plan, the scanned fail-safe
    flags (T,) and the (T, n_max, m) mask schedule — the compiled drivers'
    side of the ``mlmc.round_cost`` cost-accounting contract (beyond-cap
    rounds, j > j_max, cost 1: the correction is dropped)."""
    logs = []
    for t in range(len(levels)):
        j = int(levels[t])
        logs.append(RoundLog(j, bool(ok[t]), int(masks[t, 0].sum()),
                             round_cost(j, j_max)))
    return logs


def _mask_schedule(switcher: Switcher, T: int, n_max: int,
                   ns: np.ndarray) -> np.ndarray:
    """(T, n_max, m) identity schedule for one switcher — the vectorized
    ``mask_schedule`` fast path when ``within_round`` is the stock one, else a
    replay of the legacy driver's exact call sequence (only the n_t
    computations of each round; pad rows are never read by the level
    branches, so stateful within-round strategies stay exact)."""
    if type(switcher).within_round is Switcher.within_round:
        return switcher.mask_schedule(T, n_max)
    masks = np.zeros((T, n_max, switcher.m), bool)
    for t in range(T):
        for k in range(int(ns[t])):
            masks[t, k] = switcher.within_round(t, k)
    return masks


def _check_scan_fn_mesh(scan_fn, mesh) -> None:
    """Reject a prebuilt scan_fn whose build-time mesh disagrees with this
    run's ``mesh=``: an unsharded fn passed with a mesh would silently run
    the whole loop unsharded (and vice versa). Fns built outside
    ``make_*_scan_fn`` carry no tag and are trusted."""
    have = getattr(scan_fn, "worker_mesh", mesh)
    if (have is None) != (mesh is None) or have != mesh:
        raise ValueError(
            f"scan_fn was built with mesh={have}, but this run passes "
            f"mesh={mesh}; rebuild the scan_fn with the same mesh")


def _check_worker_mesh(mesh, worker_axis: str, m: int,
                       allow_model: bool = True) -> None:
    axes = tuple(mesh.axis_names)
    allowed = ((worker_axis,), (worker_axis, "model")) if allow_model \
        else ((worker_axis,),)
    if axes not in allowed:
        want = f"1-axis ({worker_axis!r},)" + (
            f" or 2-axis ({worker_axis!r}, 'model')" if allow_model else "")
        raise ValueError(
            f"sharded driver needs a {want} mesh, got "
            f"axes {axes} (see launch.mesh.make_worker_mesh)")
    n_dev = mesh.shape[worker_axis]
    if m % n_dev:
        raise ValueError(
            f"worker count m={m} not divisible by the {worker_axis!r} mesh "
            f"axis size {n_dev}")


def _check_lane_mesh(mesh, lane_axis: str, worker_axis: str,
                     m: Optional[int] = None) -> None:
    """Reject a sweep mesh that is not the 2-axis ``(lanes, workers)`` form
    (DESIGN.md §12); with ``m`` also checks worker divisibility (the lane
    divisibility check needs the lane count and lives in the sweep)."""
    axes = tuple(mesh.axis_names)
    if axes != (lane_axis, worker_axis):
        raise ValueError(
            f"sharded sweeps need a 2-axis ({lane_axis!r}, {worker_axis!r}) "
            f"mesh, got axes {axes} (see launch.mesh.make_lane_mesh)")
    if m is not None and m % mesh.shape[worker_axis]:
        raise ValueError(
            f"worker count m={m} not divisible by the {worker_axis!r} mesh "
            f"axis size {mesh.shape[worker_axis]}")


def _norm_mesh(mesh):
    """The sweep's 1-device bitwise contract (DESIGN.md §12): a mesh whose
    device count is 1 is the unsharded path — normalize it to ``None`` so
    the shard_map wrap is skipped entirely."""
    if mesh is None:
        return None
    if math.prod(list(mesh.shape.values())) == 1:
        return None
    return mesh


def _segment_bounds(T: int, eval_every: int, chunk: int):
    stops = {T}
    if eval_every:
        stops |= set(range(eval_every, T + 1, eval_every))
    if chunk and chunk > 0:
        stops |= set(range(chunk, T + 1, chunk))
    return sorted(stops)


def make_dynabro_scan_fn(grad_fn: GradFn, cfg: DynaBROConfig, opt: Optimizer,
                         *, mesh=None, worker_axis: str = "workers",
                         lane_attacks: Optional[Sequence[str]] = None,
                         lane_aggregators: Optional[Sequence[str]] = None,
                         param_specs=None, microbatch: bool = False,
                         sweep_mesh=None, lane_axis: str = "lanes"):
    """Build the compiled DynaBRO round loop (DESIGN.md §5, §7).

    Returns a jitted ``seg((params, opt_state), xs)`` running ``lax.scan``
    over a round schedule ``xs = (level, batches, masks, keys)`` (leading time
    axis; batches padded to n_max units, masks (n_max, m) per round). The scan
    body dispatches the host-sampled MLMC level via ``lax.switch`` whose
    branch j slices the level's nested batch prefix, applies the attack
    in-graph, robust-aggregates levels 0/J-1/J and applies the fail-safe
    combine — numerically identical to ``make_dynabro_step`` at that level.
    Reusable across ``run_dynabro_scan`` calls (jit caches per segment
    length); emits stacked (failsafe_ok, corr_norm) per round.

    With ``mesh`` (a 1-axis device mesh from ``launch.mesh.make_worker_mesh``)
    the whole segment compiles under a fully-manual ``shard_map``: the batch
    schedule is split over ``worker_axis`` so each device runs the per-worker
    gradient ``vmap`` on its local worker slice only, the stacks are
    re-assembled with a worker-axis all_gather, and the attack + aggregation
    + update code is byte-for-byte the single-device body — which is why a
    1-device mesh is bitwise-identical to ``mesh=None`` (DESIGN.md §7).

    ``lane_attacks`` (a sequence of attack names) builds the lane-batched
    sweep variant instead: the segment takes a third argument
    ``atk = (attack_id, theta)`` — a scalar index into ``lane_attacks`` plus
    the (N_PARAMS,) parameter vector, both loop-invariant — and the scan body
    dispatches the attack via a second ``lax.switch``
    (``attacks.attack_switch``). ``lane_aggregators`` does the same for the
    aggregation rule: the segment takes a fourth argument
    ``agg = (agg_id, theta, thr_coeff)`` — an index into ``lane_aggregators``,
    the (N_AGG_PARAMS,) hyperparameter vector and the lane's fail-safe
    coefficient (1+√2)·c_E·C·V — dispatched via ``agg_engine.agg_switch`` at
    every aggregation site. Either axis may be present alone (the segment
    signature is always ``seg(carry, xs, atk, agg)`` with ``None`` for the
    absent one). The MLMC level switch is untouched (its index stays scalar
    and shared across lanes). Both are mutually exclusive with ``mesh`` —
    sweeps run unsharded (DESIGN.md §7).

    A **2-axis** ``(workers, 'model')`` mesh selects the model-zoo GSPMD path
    instead (DESIGN.md §9): no shard_map — the segment jits as-is and
    ``with_sharding_constraint`` pins params / batches / the per-worker grad
    stacks, letting GSPMD compose the worker axis with FSDP+model parameter
    sharding (``param_specs``, a PartitionSpec tree over the param structure
    from ``launch.sharding.plan_params``; None = replicated params, worker
    sharding on the stacks only). On a mesh whose axes are all size 1 the
    constraints are skipped entirely, so the traced graph — and hence the
    result — is bitwise-identical to ``mesh=None`` by construction, exactly
    like the 1-axis path's skipped gather.

    ``microbatch`` streams each level-j round's 2^j units through a
    ``lax.scan`` grad-accumulation loop instead of materializing the
    (m, 2^j, ...) per-worker gradient stack: per unit k the (m, ...) worker
    grads are computed, attacked (same ``fold_in(key, k)`` keying) and summed
    into three f32 accumulators (level-0 snapshot, first-half sum, full sum)
    whose means feed the identical combine tail (``_combine_from_levels``).
    Summation order differs from the stacked path, so microbatched runs are
    *not* bitwise against non-microbatched ones — the parity contract is
    microbatched-sharded == microbatched-unsharded. Incompatible with the
    lane axes (sweeps materialize by design).

    ``sweep_mesh`` (a 2-axis ``(lanes, workers)`` mesh from
    ``launch.mesh.make_lane_mesh``) builds the sweep variants for the
    *sharded* vmapped sweep (DESIGN.md §12): the returned segment is
    un-jitted (the sweep wraps it in ``shard_map`` around the vmapped
    wrapper) and its per-worker gradient stack is re-assembled with a
    ``worker_axis`` all_gather exactly as on the 1-axis mesh path — skipped
    when the mesh's worker axis has one device, so a 1-device lane mesh
    stays bitwise-identical to the unsharded sweep by construction.
    Exclusive with ``mesh=`` and ``microbatch``.
    """
    if (lane_attacks is not None or lane_aggregators is not None) \
            and mesh is not None:
        raise ValueError(
            "lane_attacks/lane_aggregators are for the vmapped sweep, which "
            "runs unsharded; drop mesh= (DESIGN.md §7)")
    if sweep_mesh is not None:
        if mesh is not None:
            raise ValueError(
                "sweep_mesh= (the vmapped sweep's lane mesh) and mesh= (the "
                "per-run worker mesh) are exclusive; see DESIGN.md §12")
        if microbatch:
            raise ValueError(
                "microbatch streaming is not supported on the sweep "
                "variants (DESIGN.md §9); drop sweep_mesh/microbatch")
        _check_lane_mesh(sweep_mesh, lane_axis, worker_axis)
        if math.prod(list(sweep_mesh.shape.values())) > 1:
            # same backend freeze as the 1-axis mesh path: the sweep runs
            # the segment inside a shard_map region, where interpret-mode
            # pallas cannot lower (the 1-device mesh skips the shard_map
            # and so keeps dynamic dispatch — bitwise with the unsharded
            # sweep by construction)
            cfg = dataclasses.replace(
                cfg, agg_backend=agg_engine.resolve_backend(cfg.agg_backend))
    if microbatch and (lane_attacks is not None
                       or lane_aggregators is not None):
        raise ValueError(
            "microbatch streaming is not supported on the lane-batched sweep "
            "variant (DESIGN.md §9); drop lane_attacks/lane_aggregators")
    gspmd = mesh is not None and "model" in mesh.axis_names
    if param_specs is not None and not gspmd:
        raise ValueError(
            "param_specs only applies to the 2-axis (workers, 'model') GSPMD "
            "path; the 1-axis shard_map path replicates params (DESIGN.md §9)")
    if mesh is not None:
        # inside the manual shard_map region the size dispatch must never
        # pick an interpret-mode pallas kernel (the legacy lowering cannot
        # host a pallas_call there) — freeze 'auto' at build time to its
        # pre-dispatch meaning: pallas on TPU, ref elsewhere
        cfg = dataclasses.replace(
            cfg, agg_backend=agg_engine.resolve_backend(cfg.agg_backend))
    j_max = cfg.mlmc.j_max
    n_max = 2 ** j_max if cfg.use_mlmc else 1
    gather = None if gspmd else _worker_gather(
        mesh if mesh is not None else sweep_mesh, worker_axis)
    constrain = _gspmd_constraints(mesh, worker_axis, param_specs) \
        if gspmd else None
    atk_one = (attacks_lib.get_attack(cfg.attack, **(cfg.attack_kwargs or {}))
               if microbatch else None)
    atk_apply = (attacks_lib.attack_switch(tuple(lane_attacks))
                 if lane_attacks is not None else None)
    agg_apply = (agg_engine.agg_switch(tuple(lane_aggregators),
                                       backend=cfg.agg_backend, mlmc=cfg.mlmc)
                 if lane_aggregators is not None else None)

    def _stream_levels(b, params, masks, key, n: int, j: int):
        """Microbatched round body (DESIGN.md §9): stream the n units through
        a grad-accumulation scan instead of materializing the (m, n, ...)
        stack. Three f32 accumulators — the level-0 snapshot (unit k=0), the
        first-half sum and the full sum — replace the three prefix slices of
        ``_combine_levels``; their means (cast back to the grad dtype, so the
        scan carry dtype is stable) feed the identical combine tail."""
        m = masks.shape[1]
        mlmc_live = cfg.use_mlmc and 1 <= j <= j_max
        bs = jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), b)  # (n, m[_l], ..)
        zeros = jax.tree.map(
            lambda p: jnp.zeros((m,) + p.shape, jnp.float32), params)
        if constrain is not None:
            zeros = constrain.stack(zeros, lead=1)

        def unit(accs, x):
            bk, mk, k = x
            g = jax.vmap(grad_fn, in_axes=(None, 0))(params, bk)  # (m[_l], ..)
            if gather is not None:
                g = gather(g)
            if constrain is not None:
                g = constrain.stack(g, lead=1)
            g = atk_one(g, mk, key=jax.random.fold_in(key, k))
            g32 = jax.tree.map(lambda l: l.astype(jnp.float32), g)
            a0, ah, aa = accs
            a0 = jax.tree.map(lambda a, v: jnp.where(k == 0, v, a), a0, g32)
            if ah is not None:
                ah = jax.tree.map(
                    lambda a, v: jnp.where(k < n // 2, a + v, a), ah, g32)
            aa = jax.tree.map(lambda a, v: a + v, aa, g32)
            return (a0, ah, aa), ()

        accs0 = (zeros, zeros if mlmc_live else None, zeros)
        (a0, ah, aa), _ = jax.lax.scan(
            unit, accs0, (bs, masks[:n], jnp.arange(n)))

        def mean(t, c):
            return jax.tree.map(lambda l, p: (l / c).astype(p.dtype),
                                t, params)

        g0_stack = jax.tree.map(lambda l, p: l.astype(p.dtype), a0, params)
        gh = mean(ah, n // 2) if mlmc_live else None
        return _combine_from_levels(cfg, g0_stack, gh, mean(aa, n), n, j)

    def level_branch(j: int):
        n = 2 ** j if (cfg.use_mlmc and 1 <= j <= j_max) else 1

        def branch(operand):
            params, batches, masks, key, atk, agg = operand
            lane = None if atk_apply is None else (atk_apply, *atk)
            lane_agg = None if agg_apply is None else (agg_apply, *agg[:2])
            lane_thr = None if agg_apply is None else agg[2]
            b = level_prefix(batches, n, n_max, axis=1)
            if constrain is not None:
                b = constrain.batch(b)
            if microbatch:
                g, info = _stream_levels(b, params, masks, key, n, j)
                return g, info["failsafe_ok"], info["corr_norm"]
            grads = _per_worker_grads(grad_fn, params, b)  # (m[_local], n, ...)
            if gather is not None:
                grads = gather(grads)  # (m, n, ...) in worker order
            if constrain is not None:
                grads = constrain.stack(grads, lead=2)
            grads = _attack_stack(cfg, grads, masks[:n], key, lane_attack=lane)
            g, info = _combine_levels(cfg, grads, j, lane_agg=lane_agg,
                                      lane_thr=lane_thr)
            return g, info["failsafe_ok"], info["corr_norm"]

        return branch

    branches = ([level_branch(j) for j in range(1, j_max + 2)]
                if cfg.use_mlmc else [level_branch(0)])

    def body(carry, xs, atk=None, agg=None):
        params, opt_state = carry
        if constrain is not None:
            params = constrain.params(params)
        level, batches, masks, key = xs
        operand = (params, batches, masks, key, atk, agg)
        if cfg.use_mlmc:
            g, ok, dn = jax.lax.switch(level - 1, branches, operand)
        else:
            g, ok, dn = branches[0](operand)
        updates, opt_state = opt.update(g, opt_state, params)
        params = apply_updates(params, updates)
        return (params, opt_state), (ok, dn)

    if lane_attacks is not None or lane_aggregators is not None:
        def seg_lane(carry, xs, atk=None, agg=None):
            return jax.lax.scan(lambda c, x: body(c, x, atk, agg), carry, xs)

        # un-jitted: the sweep jits the vmapped wrapper anyway, and a plain
        # function can carry the branch orders for the sweep's id-consistency
        # checks (a mismatched order would silently apply the wrong attack
        # or rule per lane)
        seg_lane.lane_attacks = (tuple(lane_attacks)
                                 if lane_attacks is not None else None)
        seg_lane.lane_aggregators = (tuple(lane_aggregators)
                                     if lane_aggregators is not None else None)
        seg_lane.sweep_mesh = sweep_mesh
        return seg_lane

    def seg(carry, xs):
        return jax.lax.scan(body, carry, xs)

    if sweep_mesh is not None:
        # the no-lane-axis sweep form: un-jitted like seg_lane (the sweep
        # jits the shard_map-wrapped vmapped wrapper), tagged so the sweep
        # can reject a mesh mismatch
        seg.lane_attacks = None
        seg.lane_aggregators = None
        seg.sweep_mesh = sweep_mesh
        return seg

    if mesh is None or gspmd:
        # GSPMD path: no shard_map — the in-graph with_sharding_constraint
        # pins (or, on an all-size-1 mesh, their absence) are the whole story
        jitted = jax.jit(seg)
    else:
        jitted = jax.jit(_shard_seg(
            seg, mesh, worker_axis,
            xs_batch_axes=(None, worker_axis, None, None)))
    # tag the build mode so the drivers can reject a mismatched prebuilt fn
    # (an unsharded scan_fn passed with mesh= would silently run unsharded)
    jitted.worker_mesh = mesh
    jitted.microbatch = microbatch
    return jitted


def _worker_gather(mesh, worker_axis: str):
    """The stack re-assembly hook of the sharded scan body, or None when
    there is nothing to re-assemble (no mesh, or a 1-device mesh whose local
    slice already IS the full stack). Skipping the no-op gather on the
    1-device mesh keeps the parity contract bitwise *by construction* — even
    an identity all_gather inserts a copy that can change how XLA fuses (and
    FMA-contracts) the surrounding ops."""
    if mesh is None or mesh.shape[worker_axis] == 1:
        return None
    from repro.core.sharded import gather_worker_stack

    def gather(tree):
        return gather_worker_stack(tree, worker_axis)

    return gather


def _shard_seg(seg, mesh, worker_axis: str, xs_batch_axes):
    """Wrap a segment fn in a fully-manual ``shard_map`` over ``worker_axis``.

    Params / optimizer state / worker momenta are replicated (every device
    applies the identical update to the identical aggregate — deterministic,
    so the replication claim holds by construction); of the xs schedule only
    the batch tree is split, on its worker axis (leaf axis 1, after the time
    axis). Masks / keys / levels are replicated: the attack consumes the full
    (n, m) mask once the worker stacks are gathered.
    """
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import shard_map

    xs_specs = tuple(P(None) if a is None else P(None, a) for a in xs_batch_axes)
    return shard_map(
        seg, mesh=mesh,
        in_specs=(P(), xs_specs),
        out_specs=(P(), P(None)),
        axis_names={worker_axis}, check_vma=False)


class _GspmdConstraints:
    """``with_sharding_constraint`` pins for the 2-axis GSPMD zoo path
    (DESIGN.md §9). Unlike the 1-axis path's manual shard_map, nothing here
    rewrites the computation — the segment jits as-is and these pins only
    tell GSPMD where the parallelism lives: params per their per-leaf
    ``launch.sharding.plan_params`` specs, batches and per-worker grad
    stacks split over the worker axis. Everything else (optimizer state,
    aggregates, the update) is left to GSPMD propagation."""

    def __init__(self, mesh, worker_axis: str, param_specs):
        self.mesh = mesh
        self.worker_axis = worker_axis
        self.param_specs = param_specs

    def _pin(self, leaf, spec):
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(self.mesh, spec))

    def _specs_for(self, tree):
        """param_specs leaves aligned to ``tree``'s leaves (PartitionSpec is
        a registered pytree *leaf*, so flatten_up_to stops at each spec)."""
        return jax.tree.structure(tree).flatten_up_to(self.param_specs)

    def params(self, tree):
        """Pin params to their full FSDP/model specs; no-op when replicated."""
        if self.param_specs is None:
            return tree
        td = jax.tree.structure(tree)
        return jax.tree.unflatten(
            td, [self._pin(l, s)
                 for l, s in zip(jax.tree.leaves(tree), self._specs_for(tree))])

    def stack(self, tree, lead: int):
        """Pin a worker-stacked tree — leading (m,) (lead=1) or (m, n)
        (lead=2) axes, m split over the worker axis. Of the param dims only
        'model' entries survive: the FSDP entry IS the worker axis, already
        spent on the leading m dim, and a mesh axis cannot appear twice in
        one PartitionSpec."""
        from jax.sharding import PartitionSpec as P
        if self.param_specs is None:
            spec = P(self.worker_axis)
            return jax.tree.map(lambda l: self._pin(l, spec), tree)
        td = jax.tree.structure(tree)
        out = []
        for l, s in zip(jax.tree.leaves(tree), self._specs_for(tree)):
            tail = tuple(e if e == "model" else None for e in tuple(s))
            out.append(self._pin(
                l, P(self.worker_axis, *((None,) * (lead - 1)), *tail)))
        return jax.tree.unflatten(td, out)

    def batch(self, tree):
        """Pin per-round batches: the leading (m,) worker dim split."""
        from jax.sharding import PartitionSpec as P
        spec = P(self.worker_axis)
        return jax.tree.map(lambda l: self._pin(l, spec), tree)

    def put_params(self, tree):
        """Host-side companion to ``params``: place the initial params per
        their specs before the first segment call, so entry into the jitted
        segment starts from the sharded layout instead of committing a fully
        replicated copy first."""
        from jax.sharding import NamedSharding
        if self.param_specs is None:
            return tree
        td = jax.tree.structure(tree)
        return jax.tree.unflatten(
            td, [jax.device_put(l, NamedSharding(self.mesh, s))
                 for l, s in zip(jax.tree.leaves(tree), self._specs_for(tree))])


def _gspmd_constraints(mesh, worker_axis: str, param_specs):
    """The GSPMD pin hook, or None on an all-size-1 mesh: with every
    constraint skipped the traced graph is *identical* to ``mesh=None``,
    which is what makes the (1, 1)-mesh parity contract bitwise by
    construction (DESIGN.md §9) — the GSPMD analog of ``_worker_gather``
    returning None for a 1-device mesh."""
    if math.prod(list(mesh.shape.values())) == 1:
        return None
    return _GspmdConstraints(mesh, worker_axis, param_specs)


def run_dynabro_scan(
    grad_fn: GradFn,
    params,
    opt: Optimizer,
    cfg: DynaBROConfig,
    switcher: Switcher,
    sample_batches: Callable[[int, int], Any],
    T: int,
    seed: int = 0,
    eval_fn: Optional[Callable[[Any, int], Dict[str, float]]] = None,
    eval_every: int = 0,
    chunk: int = 0,
    scan_fn=None,
    vectorize_batches: bool = True,
    mesh=None,
    worker_axis: str = "workers",
    param_specs=None,
    microbatch: bool = False,
):
    """Compiled drop-in for ``run_dynabro``: same signature, same returns,
    round-for-round equivalent schedules (level RNG stream, switching masks,
    per-round PRNG keys, per-round batch draws).

    ``chunk`` bounds how many rounds of padded batches are resident at once
    (0 = whole segments between eval points); ``scan_fn`` accepts a prebuilt
    ``make_dynabro_scan_fn`` result for cross-run jit reuse. Pass
    ``vectorize_batches=False`` for samplers with hidden per-call state —
    the sampler is then called exactly once per round, in round order, like
    the legacy driver (see ``_batch_schedule``).

    ``mesh`` (a 1-axis worker mesh, ``launch.mesh.make_worker_mesh``) runs the
    loop sharded: per-worker gradients computed on each device's worker slice,
    the rest of the round body replicated after a worker all_gather — bitwise
    identical on a 1-device mesh, and the schedule precompute is unchanged
    (DESIGN.md §7). Requires ``switcher.m`` divisible by the mesh axis size.

    A 2-axis ``(workers, 'model')`` mesh takes the model-zoo GSPMD path
    instead, with ``param_specs`` (the PartitionSpec tree from
    ``launch.sharding.plan_params``) sharding the parameters FSDP-style over
    the worker axis and tensor-style over 'model'; ``microbatch`` streams
    each round's MLMC units through a grad-accumulation scan so no full
    (m, 2^j, ...) gradient stack is ever materialized (DESIGN.md §9). Both
    forward to ``make_dynabro_scan_fn`` — see its docstring for the parity
    contracts.

    Thin wrapper over ``repro.api.Session`` (DESIGN.md §10) — the Session
    carries the identical preflight validation and segment loop.
    """
    from repro.api.session import Session
    sess = Session(cfg, grad_fn=grad_fn, params0=params, opt=opt,
                   switcher=switcher, sample_batches=sample_batches,
                   seed=seed, scan_fn=scan_fn,
                   vectorize_batches=vectorize_batches, mesh=mesh,
                   worker_axis=worker_axis, param_specs=param_specs,
                   microbatch=microbatch)
    return sess.run(T, eval_fn=eval_fn, eval_every=eval_every, chunk=chunk)


def make_momentum_scan_fn(grad_fn: GradFn, cfg: DynaBROConfig, lr: float,
                          beta: float, *, mesh=None,
                          worker_axis: str = "workers"):
    """Compiled worker-momentum baseline loop: the shared round body of
    ``make_momentum_step``, scanned over (batches, masks, keys) schedules.
    ``mesh`` (1-axis only — the 2-axis GSPMD zoo path is DynaBRO-only,
    DESIGN.md §9) shards the per-worker gradient vmap across devices exactly
    as in ``make_dynabro_scan_fn`` (worker momenta stay replicated)."""
    if mesh is not None and "model" in mesh.axis_names:
        raise ValueError(
            "momentum scan driver supports only 1-axis worker meshes; the "
            "2-axis (workers, 'model') GSPMD path is DynaBRO-only "
            "(DESIGN.md §9)")
    if mesh is not None:
        # same backend freeze as make_dynabro_scan_fn: no interpret-mode
        # pallas inside the manual shard_map region
        cfg = dataclasses.replace(
            cfg, agg_backend=agg_engine.resolve_backend(cfg.agg_backend))
    round_fn = _make_momentum_round(grad_fn, cfg, lr, beta,
                                    gather=_worker_gather(mesh, worker_axis))

    def body(carry, xs):
        batch, mask, key = xs
        return round_fn(carry[0], carry[1], batch, mask, key), ()

    def seg(carry, xs):
        return jax.lax.scan(body, carry, xs)

    if mesh is None:
        jitted = jax.jit(seg)
    else:
        jitted = jax.jit(_shard_seg(seg, mesh, worker_axis,
                                    xs_batch_axes=(worker_axis, None, None)))
    jitted.worker_mesh = mesh
    return jitted


def run_momentum_scan(
    grad_fn: GradFn,
    params,
    cfg: DynaBROConfig,
    switcher: Switcher,
    sample_batches: Callable[[int, int], Any],
    T: int,
    lr: float,
    beta: float,
    seed: int = 0,
    eval_fn: Optional[Callable[[Any, int], Dict[str, float]]] = None,
    eval_every: int = 0,
    chunk: int = 0,
    scan_fn=None,
    vectorize_batches: bool = True,
    mesh=None,
    worker_axis: str = "workers",
):
    """Compiled drop-in for ``run_momentum`` (same signature + chunking).
    ``mesh`` runs it sharded over the worker axis (1-axis meshes only,
    DESIGN.md §7).

    Thin wrapper over ``repro.api.Session`` (DESIGN.md §10)."""
    from repro.api.session import Session
    sess = Session(cfg, grad_fn=grad_fn, params0=params, mode="momentum",
                   lr=lr, beta=beta, switcher=switcher,
                   sample_batches=sample_batches, seed=seed, scan_fn=scan_fn,
                   vectorize_batches=vectorize_batches, mesh=mesh,
                   worker_axis=worker_axis)
    return sess.run(T, eval_fn=eval_fn, eval_every=eval_every, chunk=chunk)


# ----------------------------------------------- vmapped scenario sweeps
#
# Whole attack × switcher × aggregator grids re-run the compiled driver per
# cell; cells that differ only in their *switching strategy, attack and
# attack kwargs* share every other schedule (the level RNG stream, per-round
# keys and batch draws depend on the seed alone), so they can run as lanes of
# one vmapped scan instead of C sequential driver calls (DESIGN.md §7).
# ``jax.vmap`` returns a fresh function object per call, so jitting it anew
# on every sweep would miss the compile cache each time. The wrapper cache is
# a small MRU list keyed on scan_fn identity: repeated sweeps over
# caller-held scan_fns stay in steady state even when the caller alternates
# several of them — e.g. the attack-sweep benchmark's baseline, which cycles
# one prebuilt scan_fn per attack group every timed iteration and would
# recompile on every call under a 1-slot cache. Ad-hoc scan_fns (including
# ``run_matrix_vmapped``'s per-group builds, which are fresh objects each
# call and can never be re-looked-up) miss and age out; retention is bounded
# at ``_VMAPPED_CACHE_SIZE`` wrappers. (A weak/keyed map cannot do better:
# the wrapper closes over scan_fn, so any cache holding the wrapper pins
# its key.)

_VMAPPED_CACHE: list = []  # MRU-first [(scan_fn, config_key, vseg), ...]
_VMAPPED_CACHE_SIZE = 8


def _shard_sweep(vseg, mesh, lane_axis: str, worker_axis: str, *,
                 lane: bool, replicated: bool):
    """Wrap the vmapped sweep segment in ``shard_map`` over a 2-axis
    ``(lanes, workers)`` mesh (DESIGN.md §12): lanes are split over the lane
    axis (carry, mask schedule and the per-lane attack/agg plans), the batch
    schedule over the worker axis (the segment re-assembles the gradient
    stacks with a worker all_gather, exactly as on the 1-axis mesh path);
    levels and keys are replicated. Callers skip this wrap entirely on a
    1-device mesh — the bitwise contract by construction, as in
    ``_worker_gather``."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import shard_map

    lanes = P(lane_axis)
    # batch leaves: (L, m, n_max, ...) — or (R, L, m, ...) with a replicate
    # axis — split on the worker dim; masks lead with the lane (cell) axis
    batch_spec = P(None, None, worker_axis) if replicated \
        else P(None, worker_axis)
    xs_specs = (P(), batch_spec, lanes, P())
    in_specs = (lanes, xs_specs) + ((lanes, lanes) if lane else ())
    return shard_map(vseg, mesh=mesh, in_specs=in_specs,
                     out_specs=(lanes, lanes),
                     axis_names={lane_axis, worker_axis}, check_vma=False)


def _vmapped_scan_fn(scan_fn, lane: bool = False, replicated: bool = False,
                     lane_mesh=None, lane_axis: str = "lanes",
                     worker_axis: str = "workers"):
    """Lane-batched segment fn: model/optimizer state and the mask schedule
    are mapped over the lane axis; levels / batches / keys stay shared (they
    depend only on the sweep seed) — crucially the ``lax.switch`` level index
    stays a scalar, keeping the one-branch-per-round dispatch. With ``lane``
    the segment's extra ``atk = (attack_id, theta)`` and ``agg = (agg_id,
    theta, thr_coeff)`` arguments are mapped over lanes as well (both
    dispatches are per-lane data; an absent axis is just ``None``, an empty
    pytree vmap maps over trivially).

    ``replicated`` nests a second vmap for the replicate axis (DESIGN.md
    §12): the outer map stays the cell axis above; the inner map runs each
    cell's replicates over per-replicate batch schedules (leading R axis),
    masks (cells carry a (C, R, T, n_max, m) schedule) and key streams
    ((R, T, 2)), while the level plan — and with it the ``lax.switch``
    index — stays scalar and shared, and the per-lane attack/agg plans stay
    per-cell. ``lane_mesh`` (2-axis, multi-device) additionally wraps the
    result in ``_shard_sweep``; a 1-device mesh is ignored here so the
    traced graph is the unsharded one (bitwise by construction)."""
    if lane_mesh is not None and \
            math.prod(list(lane_mesh.shape.values())) == 1:
        lane_mesh = None
    key = (lane, replicated, lane_mesh, lane_axis, worker_axis)
    for i, entry in enumerate(_VMAPPED_CACHE):
        if entry[0] is scan_fn and entry[1] == key:
            _VMAPPED_CACHE.insert(0, _VMAPPED_CACHE.pop(i))
            return entry[2]
    inner = scan_fn
    if replicated:
        rep_axes = ((0, 0), (None, 0, 0, 0))
        if lane:
            rep_axes = rep_axes + (None, None)
        inner = jax.vmap(scan_fn, in_axes=rep_axes)
    in_axes = ((0, 0), (None, None, 0, None))
    if lane:
        in_axes = in_axes + (0, 0)
    vseg = jax.vmap(inner, in_axes=in_axes)
    if lane_mesh is not None:
        vseg = _shard_sweep(vseg, lane_mesh, lane_axis, worker_axis,
                            lane=lane, replicated=replicated)
    vseg = jax.jit(vseg)
    _VMAPPED_CACHE.insert(0, (scan_fn, key, vseg))
    del _VMAPPED_CACHE[_VMAPPED_CACHE_SIZE:]
    return vseg


def _norm_lane_specs(specs):
    out = []
    for a in specs:
        name, kw = (a, {}) if isinstance(a, str) else (a[0], dict(a[1] or {}))
        out.append((name, kw))
    return out


def _lane_attack_plan(attacks):
    """Normalize per-lane attack specs (a name or ``(name, kwargs)``) into
    the compact dispatch plan: the tuple of distinct names in
    first-appearance order (the ``lax.switch`` branch set), the (C,) int32
    lane->branch index vector and the (C, N_PARAMS) parameter matrix."""
    specs = _norm_lane_specs(attacks)
    names = tuple(dict.fromkeys(name for name, _ in specs))
    ids = np.array([names.index(name) for name, _ in specs], np.int32)
    thetas = np.stack([attacks_lib.attack_theta(name, kw)
                       for name, kw in specs])
    return names, ids, thetas


def _lane_agg_plan(aggregators, cfg: DynaBROConfig):
    """The aggregator-axis analog of ``_lane_attack_plan``: distinct rule
    names (the ``agg_switch`` branch set), lane->branch ids, the
    (C, N_AGG_PARAMS) theta matrix — plus the (C,) fail-safe coefficient
    vector, because each lane's c_E follows its rule exactly as
    ``scenarios._cell_cfg`` sets it per cell: MFM runs the paper's
    δ-oblivious Option 2, every other rule Option 1 with ``cfg`` kappa."""
    specs = _norm_lane_specs(aggregators)
    names = tuple(dict.fromkeys(name for name, _ in specs))
    ids = np.array([names.index(name) for name, _ in specs], np.int32)
    thetas = np.stack([agg_engine.agg_theta(name, kw) for name, kw in specs])
    coeffs = np.array(
        [dataclasses.replace(
            cfg.mlmc, option=2 if name == "mfm" else 1).threshold_coeff
         for name, _ in specs], np.float32)
    return names, ids, thetas, coeffs


def run_dynabro_scan_sweep(
    grad_fn: GradFn,
    params,
    opt: Optimizer,
    cfg: DynaBROConfig,
    switchers,
    sample_batches: Callable[[int, int], Any],
    T: int,
    seed: int = 0,
    chunk: int = 0,
    scan_fn=None,
    vectorize_batches: bool = True,
    attacks=None,
    aggregators=None,
):
    """Run C = len(switchers) DynaBRO cells as one vmapped compiled loop.

    Every cell shares ``cfg`` / ``seed`` / ``sample_batches`` and differs
    only in its switcher — and, with ``attacks`` / ``aggregators``, in its
    attack and aggregation rule — so the level / key / batch schedules
    coincide and stay *un-batched* under ``vmap`` — in particular the
    ``lax.switch`` level dispatch keeps its scalar index (a batched index
    would degrade to execute-all-branches-and-select). Only the
    (C, T, n_max, m) mask schedule, the model/optimizer state and the
    per-lane attack/aggregator ids + parameters are batched over lanes.

    ``attacks`` (one spec per lane: a name or ``(name, kwargs)``) lets lanes
    differ in attack and attack kwargs: the sweep builds a per-lane (C,)
    attack-index vector into the compact set of distinct names plus a
    (C, N_PARAMS) parameter matrix (``attacks.attack_theta``), and the scan
    body dispatches each lane's attack via ``lax.switch`` over the uniform
    ``(stacked, mask, key, theta)`` implementations — under vmap this lowers
    to execute-all-branches-and-select, cheap because attacks are O(m·d)
    next to the per-worker gradient work. ``attacks=None`` keeps every lane
    on ``cfg.attack`` through the original static path, bitwise-unchanged.

    ``aggregators`` (same spec shape; kwargs are rule hyperparameters like
    ``delta`` / ``tau`` / ``multi`` / ``iters``) does the same for the
    aggregation rule via ``agg_engine.agg_switch`` over the uniform
    ``(stacked, n, theta)`` forms — so grids varying only an aggregator
    hyperparameter (CWTM at several δ) are free lanes, and each lane also
    carries its own fail-safe coefficient (MFM lanes run the Option-2 c_E,
    see ``_lane_agg_plan``). ``aggregators=None`` keeps every lane on
    ``cfg.aggregator`` through the static path, bitwise-unchanged.

    Mixed-rule grids are split **branch-homogeneously**: lanes are grouped
    by aggregator name (one sub-sweep per distinct rule, lanes permuted into
    groups and results un-permuted back to the caller's lane order), so each
    group's ``agg_switch`` has a single branch and skips the ``lax.switch``
    entirely — a 4-rule grid pays each rule's cost once per group instead of
    every lane paying all four under the vmapped switch's
    execute-all-branches-and-select (DESIGN.md §7). Grouping applies when
    ``scan_fn`` is None (one scan_fn built per group) or a *Mapping*
    ``{rule_name: scan_fn}`` with exactly the grid's distinct rule names as
    keys, each value a prebuilt ``make_dynabro_scan_fn(...,
    lane_aggregators=(rule_name,))`` (plus this sweep's attack names) — the
    steady-state form benchmarks use, since per-call rebuilt scan_fns miss
    ``_vmapped_scan_fn``'s identity-keyed cache. A plain prebuilt scan_fn
    runs the grid as one multi-branch dispatch, exactly as before.

    Returns ``[(params_c, logs_c), ...]`` in input order, each lane equal to
    the corresponding ``run_dynabro_scan(...)`` call with that lane's
    switcher, attack and aggregator — usually bitwise, always within the
    parity suite's 1e-6 tolerance (XLA may reorder float ops at ULP level
    when it fuses the batched body; the round logs match exactly — locked by
    tests/test_scenarios.py). ``scan_fn`` accepts a prebuilt *unsharded*
    ``make_dynabro_scan_fn`` result and must match both lane axes: built
    with ``lane_attacks=`` / ``lane_aggregators=`` equal to the distinct
    names (first-appearance order) this sweep derives, and without either
    when the corresponding axis is absent. The jitted vmap wrapper is
    memoized per scan_fn (``_vmapped_scan_fn``), so repeated sweeps with
    shared scan_fns reuse one compile cache.

    Thin wrapper over ``repro.api.Session.sweep`` driven by a validated
    ``repro.api.SweepSpec`` (DESIGN.md §10). The raw kwarg forms here remain
    a one-release compatibility layer; the ``{rule_name: scan_fn}`` mapping
    kwarg additionally warns — carry prebuilt group fns in
    ``SweepSpec.scan_fn`` instead.
    """
    from repro.api.session import Session
    from repro.api.specs import SweepSpec
    if isinstance(scan_fn, Mapping):
        warnings.warn(
            "passing scan_fn as a raw {rule_name: scan_fn} mapping kwarg is "
            "deprecated and will be removed after one release; carry it in "
            "repro.api.SweepSpec(..., scan_fn=...) and run "
            "Session.sweep(spec, T) (DESIGN.md §10)",
            DeprecationWarning, stacklevel=2)
    spec = SweepSpec(
        switchers=tuple(switchers),
        attacks=None if attacks is None else tuple(attacks),
        aggregators=None if aggregators is None else tuple(aggregators),
        scan_fn=scan_fn)
    sess = Session(cfg, grad_fn=grad_fn, params0=params, opt=opt,
                   sample_batches=sample_batches, seed=seed,
                   vectorize_batches=vectorize_batches)
    return sess.sweep(spec, T, chunk=chunk)
