"""Mode B — production sharded DynaBRO (multi-pod scale-out).

The paper's server aggregates m full gradients; at 398B–480B parameters that
is infeasible as stated. Key observation (DESIGN.md §3): the aggregation rules
used in the paper's experiments (CWMed / CWTM / Mean) are **coordinate-wise**,
so the aggregation itself can be sharded across every chip: replace the
data-parallel reduce-scatter with an **all-to-all** along the worker axes —
each device receives the m worker values for its own parameter shard and
aggregates locally. Same per-link communication volume as reduce-scatter.

``robust_all_gather`` packages this as a custom-VJP around the FSDP param
all-gather:

    forward : p_shard --all-gather(workers)--> p_full
    backward: per-worker cotangent gᵢ --[simulated Byzantine attack]
              --all-to-all(workers)--> (m, shard) --robust agg--> ĝ_shard

Because the hook is applied *inside* the layer-group scan, per-worker full
gradients only ever exist one layer-group at a time — this is what makes
Byzantine-robust training of the mega-architectures fit in HBM.

Byzantine workers are *simulated*: the attack corrupts the cotangent of the
workers flagged by the (m,)-float mask (worker index = flattened
``lax.axis_index`` over the worker axes). IPM/ALIE compute honest statistics
with psum collectives — the exact omniscient attacks of Appendix J.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class ShardedByzConfig:
    axis_names: Tuple[str, ...]  # worker axes, e.g. ('data',) or ('pod','data')
    m: int  # product of worker axis sizes
    aggregator: str = "cwmed"  # coordinate-wise: mean | cwmed | cwtm
    delta: float = 0.25
    attack: str = "none"  # none | sign_flip | ipm | alie
    attack_param: float = 0.1


# ------------------------------------------------------------ aggregation


def _agg_subaxis(stack: jax.Array, cfg: ShardedByzConfig) -> jax.Array:
    """stack: (m, ...) -> (...). Coordinate-wise robust aggregation."""
    x = stack.astype(jnp.float32)
    if cfg.aggregator == "mean":
        return x.mean(0)
    if cfg.aggregator == "cwmed":
        return jnp.median(x, axis=0)
    if cfg.aggregator == "cwtm":
        m = x.shape[0]
        t = min(int(-(-cfg.delta * m // 1)), (m - 1) // 2)
        xs = jnp.sort(x, axis=0)
        return xs[t:m - t].mean(0) if t else xs.mean(0)
    raise ValueError(f"sharded mode supports coordinate-wise rules, got {cfg.aggregator}")


def _attack_cotangent(g: jax.Array, maskf: jax.Array, cfg: ShardedByzConfig) -> jax.Array:
    """Corrupt this worker's cotangent if it is flagged Byzantine."""
    if cfg.attack == "none":
        return g
    idx = lax.axis_index(cfg.axis_names)
    byz = maskf[idx] > 0.5
    gf = g.astype(jnp.float32)
    n_honest = jnp.maximum(cfg.m - maskf.sum(), 1.0)
    if cfg.attack == "sign_flip":
        bad = -gf
    elif cfg.attack == "ipm":
        hsum = lax.psum(jnp.where(byz, 0.0, 1.0) * gf, cfg.axis_names)
        bad = -cfg.attack_param * hsum / n_honest
    elif cfg.attack == "alie":
        hg = jnp.where(byz, 0.0, 1.0) * gf
        mu = lax.psum(hg, cfg.axis_names) / n_honest
        var = lax.psum(jnp.where(byz, 0.0, 1.0) * jnp.square(gf - mu),
                       cfg.axis_names) / n_honest
        bad = mu - cfg.attack_param * jnp.sqrt(var + 1e-12)
    else:
        raise ValueError(cfg.attack)
    return jnp.where(byz, bad, gf).astype(g.dtype)


# ------------------------------------------------------------ custom VJPs


def make_robust_gather(cfg: ShardedByzConfig, gather_axis: int):
    """FSDP all-gather whose backward robust-aggregates instead of summing."""

    @jax.custom_vjp
    def rg(p, maskf):
        return lax.all_gather(p, cfg.axis_names, axis=gather_axis, tiled=True)

    def fwd(p, maskf):
        return rg(p, maskf), maskf

    def bwd(maskf, g):
        g = _attack_cotangent(g, maskf, cfg)
        # exchange: every device ends up with the m worker values of its shard
        ex = lax.all_to_all(g, cfg.axis_names, split_axis=gather_axis,
                            concat_axis=gather_axis, tiled=True)
        shp = ex.shape
        blk = shp[gather_axis] // cfg.m
        ex = ex.reshape(shp[:gather_axis] + (cfg.m, blk) + shp[gather_axis + 1:])
        ex = jnp.moveaxis(ex, gather_axis, 0)  # (m, ..., blk, ...)
        agg = _agg_subaxis(ex, cfg)
        return agg.astype(g.dtype), jnp.zeros_like(maskf)

    rg.defvjp(fwd, bwd)
    return rg


def make_robust_replicated(cfg: ShardedByzConfig):
    """Identity on replicated params; backward gathers the m cotangents and
    robust-aggregates them (small leaves: norms, biases, routers)."""

    @jax.custom_vjp
    def rr(p, maskf):
        return p

    def fwd(p, maskf):
        return rr(p, maskf), maskf

    def bwd(maskf, g):
        g = _attack_cotangent(g, maskf, cfg)
        stack = lax.all_gather(g, cfg.axis_names, axis=0, tiled=False)  # (m, ...)
        return _agg_subaxis(stack, cfg).astype(g.dtype), jnp.zeros_like(maskf)

    rr.defvjp(fwd, bwd)
    return rr


# ------------------------------------------------------------ param hook


def fsdp_axis_for(shape: Sequence[int], m: int, model_axis: Optional[int],
                  min_size: int = 1 << 16) -> Optional[int]:
    """Deterministic FSDP-axis rule shared by the spec builder and the hook:
    first axis (≠ model axis) divisible by the worker count, on leaves big
    enough to be worth sharding."""
    size = 1
    for s in shape:
        size *= s
    if size < min_size:
        return None
    for ax, s in enumerate(shape):
        if ax != model_axis and s % m == 0:
            return ax
    return None


def make_param_hook(cfg: ShardedByzConfig, plans: dict, maskf: jax.Array):
    """Tree hook with robust-aggregating backward.

    ``plans``: {scope: plan-tree}, plan trees structurally matching what the
    hook is called on (scope 'blocks' = one group slice; scope 'top' = the
    non-block params), each leaf an int FSDP axis (-1 => replicated).
    Built once on global shapes by ``launch.sharding.plan_params``.
    """
    rr = make_robust_replicated(cfg)
    gathers = {ax: make_robust_gather(cfg, ax) for ax in range(4)}

    def hook(tree, scope: str):
        plan = plans[scope]

        def leaf(p, fa):
            if fa < 0:
                return rr(p, maskf)
            return gathers[fa](p, maskf)

        return jax.tree.map(leaf, tree, plan)

    return hook


def tree_sq_norm(grads, plans_full: dict, axis_names) -> jax.Array:
    """Global ‖g‖² of a Mode-B gradient tree inside the manual region.

    FSDP-sharded leaves (plan >= 0) hold disjoint coordinate blocks per worker
    => psum their partial sums over the worker axes; replicated leaves (-1)
    are identical on every worker => no psum."""
    sq_sharded = jnp.zeros((), jnp.float32)
    sq_repl = jnp.zeros((), jnp.float32)
    flat_g, _ = jax.tree_util.tree_flatten(grads)
    flat_p, _ = jax.tree_util.tree_flatten(plans_full)
    for g, fa in zip(flat_g, flat_p):
        s = jnp.sum(jnp.square(g.astype(jnp.float32)))
        if fa >= 0:
            sq_sharded = sq_sharded + s
        else:
            sq_repl = sq_repl + s
    return lax.psum(sq_sharded, axis_names) + sq_repl
