"""Mode B — production sharded DynaBRO (multi-pod scale-out).

The paper's server aggregates m full gradients; at 398B–480B parameters that
is infeasible as stated. Key observation (DESIGN.md §3): the aggregation rules
used in the paper's experiments (CWMed / CWTM / Mean) are **coordinate-wise**,
so the aggregation itself can be sharded across every chip: replace the
data-parallel reduce-scatter with an **all-to-all** along the worker axes —
each device receives the m worker values for its own parameter shard and
aggregates locally. Same per-link communication volume as reduce-scatter.

``robust_all_gather`` packages this as a custom-VJP around the FSDP param
all-gather:

    forward : p_shard --all-gather(workers)--> p_full
    backward: per-worker cotangent gᵢ --[simulated Byzantine attack]
              --all-to-all(workers)--> (m, shard) --robust agg--> ĝ_shard

Because the hook is applied *inside* the layer-group scan, per-worker full
gradients only ever exist one layer-group at a time — this is what makes
Byzantine-robust training of the mega-architectures fit in HBM.

The per-shard aggregation itself dispatches through the shared engine
registry (``core.agg_engine``, DESIGN.md §4): the same rule objects Mode A
uses, with ref/pallas backends — so the Pallas kernels serve the Mode B
backward too.

Byzantine workers are *simulated*: the attack corrupts the cotangent of the
workers flagged by the (m,)-float mask (worker index = flattened position
along the worker axes, delivered as data — see ``make_param_hook``). IPM/ALIE
compute honest statistics with psum collectives — the exact omniscient
attacks of Appendix J.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.agg_engine import get_aggregator, resolve_backend


@dataclasses.dataclass(frozen=True)
class ShardedByzConfig:
    axis_names: Tuple[str, ...]  # worker axes, e.g. ('data',) or ('pod','data')
    m: int  # product of worker axis sizes
    aggregator: str = "cwmed"  # coordinate-wise: mean | cwmed | cwtm
    delta: float = 0.25
    attack: str = "none"  # none | sign_flip | ipm | alie
    attack_param: float = 0.1
    backend: str = "auto"  # agg_engine backend: ref | pallas | auto


# ------------------------------------------------------------ aggregation


def _make_leaf_agg(cfg: ShardedByzConfig):
    """(m, ...) -> (...) robust aggregation via the shared engine registry.

    Mode B aggregates each parameter shard independently, which is exact only
    for coordinate-wise rules (DESIGN.md §3) — the engine's registry carries
    that metadata, so misconfiguration fails at build time, not in backward."""
    # resolve 'auto' eagerly (pallas on TPU, ref elsewhere): the leaf runs
    # inside the partial-manual shard_map region, where the per-call size
    # dispatch must never route a big leaf to an interpret-mode pallas call
    # the legacy manual lowering cannot host
    agg = get_aggregator(cfg.aggregator, delta=cfg.delta,
                         backend=resolve_backend(cfg.backend))
    if not agg.coordinate_wise:
        raise ValueError(
            f"sharded mode supports coordinate-wise rules, got {cfg.aggregator}")
    return agg.leaf


def _attack_cotangent(g: jax.Array, maskf: jax.Array, idx: jax.Array,
                      cfg: ShardedByzConfig) -> jax.Array:
    """Corrupt this worker's cotangent if it is flagged Byzantine. ``idx`` is
    this device's flattened worker index (scalar int32, arrives as data)."""
    if cfg.attack == "none":
        return g
    byz = maskf[idx] > 0.5
    gf = g.astype(jnp.float32)
    n_honest = jnp.maximum(cfg.m - maskf.sum(), 1.0)
    if cfg.attack == "sign_flip":
        bad = -gf
    elif cfg.attack == "ipm":
        hsum = lax.psum(jnp.where(byz, 0.0, 1.0) * gf, cfg.axis_names)
        bad = -cfg.attack_param * hsum / n_honest
    elif cfg.attack == "alie":
        hg = jnp.where(byz, 0.0, 1.0) * gf
        mu = lax.psum(hg, cfg.axis_names) / n_honest
        var = lax.psum(jnp.where(byz, 0.0, 1.0) * jnp.square(gf - mu),
                       cfg.axis_names) / n_honest
        bad = mu - cfg.attack_param * jnp.sqrt(var + 1e-12)
    else:
        raise ValueError(cfg.attack)
    return jnp.where(byz, bad, gf).astype(g.dtype)


# ------------------------------------------------------------ collectives
#
# jax <= 0.4.x cannot lower worker-axis all_gather / all_to_all inside a
# *partial*-manual shard_map (the XLA SPMD partitioner check-fails on the
# ManualSubgroup sharding), and ``lax.axis_index`` lowers to a PartitionId op
# XLA rejects under partial SPMD. The worker index therefore always arrives
# as *data* (an iota sharded over the worker axes — see ``make_param_hook``),
# and on legacy jax the gathers are emulated with psum + dynamic slicing:
# identical results, m× the gather bytes, never on the production (new-jax
# TPU) path.

from repro.compat import LEGACY_PARTIAL_MANUAL as _LEGACY_PARTIAL_MANUAL  # noqa: E402


def _gather_tiled(p: jax.Array, cfg: ShardedByzConfig, axis: int,
                  idx: jax.Array) -> jax.Array:
    """FSDP all-gather along `axis` over the worker axes."""
    if not _LEGACY_PARTIAL_MANUAL:
        return lax.all_gather(p, cfg.axis_names, axis=axis, tiled=True)
    full = jnp.zeros(p.shape[:axis] + (p.shape[axis] * cfg.m,)
                     + p.shape[axis + 1:], p.dtype)
    starts = (0,) * axis + (idx * p.shape[axis],) + (0,) * (p.ndim - axis - 1)
    return lax.psum(lax.dynamic_update_slice(full, p, starts), cfg.axis_names)


def _gather_stack(g: jax.Array, cfg: ShardedByzConfig, idx: jax.Array) -> jax.Array:
    """(...) -> (m, ...): stack the m workers' values of a same-shape array."""
    if not _LEGACY_PARTIAL_MANUAL:
        return lax.all_gather(g, cfg.axis_names, axis=0, tiled=False)
    full = jnp.zeros((cfg.m,) + g.shape, g.dtype)
    starts = (idx,) + (0,) * g.ndim
    return lax.psum(lax.dynamic_update_slice(full, g[None], starts), cfg.axis_names)


def _exchange_worker_blocks(g: jax.Array, cfg: ShardedByzConfig, axis: int,
                            idx: jax.Array) -> jax.Array:
    """Worker all-to-all: full-size cotangent -> (m, ..., blk, ...) holding
    every worker's values for this device's own parameter shard."""
    if not _LEGACY_PARTIAL_MANUAL:
        ex = lax.all_to_all(g, cfg.axis_names, split_axis=axis,
                            concat_axis=axis, tiled=True)
        shp = ex.shape
        blk = shp[axis] // cfg.m
        ex = ex.reshape(shp[:axis] + (cfg.m, blk) + shp[axis + 1:])
        return jnp.moveaxis(ex, axis, 0)
    stack = _gather_stack(g, cfg, idx)  # (m, ..., d, ...)
    blk = g.shape[axis] // cfg.m
    starts = (0,) * (axis + 1) + (idx * blk,) + (0,) * (g.ndim - axis - 1)
    sizes = (cfg.m,) + g.shape[:axis] + (blk,) + g.shape[axis + 1:]
    return lax.dynamic_slice(stack, starts, sizes)


# ------------------------------------------------- Mode A sharded substrate
#
# The compiled Mode A drivers (``core.robust_train.run_dynabro_scan``) reuse
# this module's substrate to lay the m simulated workers across devices: the
# per-worker gradient computation runs on each device's local worker slice,
# then the stacks are re-assembled with a worker-axis all_gather so the attack
# + aggregation code is *identical* to the single-device driver (DESIGN.md
# §7 — this is what makes the 1-device parity contract bitwise). Unlike the
# Mode B hooks above, the driver's shard_map region is *fully* manual (the
# mesh has only worker axes), which legacy jax lowers fine — no psum
# emulation needed.


def gather_worker_stack(tree, axis_names):
    """(m_local, ...)-leaf tree -> (m, ...) in device order, inside a
    fully-manual shard_map region over ``axis_names``."""
    return jax.tree.map(
        lambda l: lax.all_gather(l, axis_names, axis=0, tiled=True), tree)


# ------------------------------------------------------------ custom VJPs


def make_robust_gather(cfg: ShardedByzConfig, gather_axis: int):
    """FSDP all-gather whose backward robust-aggregates instead of summing."""
    leaf_agg = _make_leaf_agg(cfg)

    @jax.custom_vjp
    def rg(p, maskf, widx):  # widx: f32 scalar worker index (see make_param_hook)
        return _gather_tiled(p, cfg, gather_axis, widx.astype(jnp.int32))

    def fwd(p, maskf, widx):
        return rg(p, maskf, widx), (maskf, widx)

    def bwd(res, g):
        maskf, widx = res
        idx = widx.astype(jnp.int32)
        g = _attack_cotangent(g, maskf, idx, cfg)
        # exchange: every device ends up with the m worker values of its shard
        ex = _exchange_worker_blocks(g, cfg, gather_axis, idx)
        return (leaf_agg(ex).astype(g.dtype), jnp.zeros_like(maskf),
                jnp.zeros_like(widx))

    rg.defvjp(fwd, bwd)
    return rg


def make_robust_replicated(cfg: ShardedByzConfig):
    """Identity on replicated params; backward gathers the m cotangents and
    robust-aggregates them (small leaves: norms, biases, routers)."""
    leaf_agg = _make_leaf_agg(cfg)

    @jax.custom_vjp
    def rr(p, maskf, widx):
        return p

    def fwd(p, maskf, widx):
        return rr(p, maskf, widx), (maskf, widx)

    def bwd(res, g):
        maskf, widx = res
        idx = widx.astype(jnp.int32)
        g = _attack_cotangent(g, maskf, idx, cfg)
        stack = _gather_stack(g, cfg, idx)  # (m, ...)
        return (leaf_agg(stack).astype(g.dtype), jnp.zeros_like(maskf),
                jnp.zeros_like(widx))

    rr.defvjp(fwd, bwd)
    return rr


# ------------------------------------------------------------ param hook


def fsdp_axis_for(shape: Sequence[int], m: int, model_axis: Optional[int],
                  min_size: int = 1 << 16) -> Optional[int]:
    """Deterministic FSDP-axis rule shared by the spec builder and the hook:
    first axis (≠ model axis) divisible by the worker count, on leaves big
    enough to be worth sharding."""
    size = 1
    for s in shape:
        size *= s
    if size < min_size:
        return None
    for ax, s in enumerate(shape):
        if ax != model_axis and s % m == 0:
            return ax
    return None


def make_param_hook(cfg: ShardedByzConfig, plans: dict, maskf: jax.Array,
                    widx: jax.Array):
    """Tree hook with robust-aggregating backward.

    ``plans``: {scope: plan-tree}, plan trees structurally matching what the
    hook is called on (scope 'blocks' = one group slice; scope 'top' = the
    non-block params), each leaf an int FSDP axis (-1 => replicated).
    Built once on global shapes by ``launch.sharding.plan_params``.

    ``widx``: this device's flattened worker index, delivered as data (the
    step builders feed an iota sharded over the worker axes — the local slice
    is the index). Any shape with one element; forwarded as an f32 scalar.
    """
    rr = make_robust_replicated(cfg)
    gathers = {ax: make_robust_gather(cfg, ax) for ax in range(4)}
    widx = jnp.asarray(widx, jnp.float32).reshape(())

    def hook(tree, scope: str):
        plan = plans[scope]

        def leaf(p, fa):
            if fa < 0:
                return rr(p, maskf, widx)
            return gathers[fa](p, maskf, widx)

        return jax.tree.map(leaf, tree, plan)

    return hook


def tree_sq_norm(grads, plans_full: dict, axis_names) -> jax.Array:
    """Global ‖g‖² of a Mode-B gradient tree inside the manual region.

    FSDP-sharded leaves (plan >= 0) hold disjoint coordinate blocks per worker
    => psum their partial sums over the worker axes; replicated leaves (-1)
    are identical on every worker => no psum."""
    sq_sharded = jnp.zeros((), jnp.float32)
    sq_repl = jnp.zeros((), jnp.float32)
    flat_g, _ = jax.tree_util.tree_flatten(grads)
    flat_p, _ = jax.tree_util.tree_flatten(plans_full)
    for g, fa in zip(flat_g, flat_p):
        s = jnp.sum(jnp.square(g.astype(jnp.float32)))
        if fa >= 0:
            sq_sharded = sq_sharded + s
        else:
            sq_repl = sq_repl + s
    return lax.psum(sq_sharded, axis_names) + sq_repl


def make_global_norm(plans: dict, axis_names):
    """``norm_fn`` for ``mlmc.mlmc_combine`` inside the Mode-B manual region:
    the global ℓ2 norm of a worker-sharded gradient tree, assembled with one
    scalar psum over the worker axes (``tree_sq_norm``). ``plans`` is the
    plan tree from ``launch.sharding.plan_params`` ({'top': ..., 'blocks':
    ...}); the flattened full-tree plan is rebuilt here so every caller
    shares one layout convention."""
    plans_full = {k: v for k, v in plans["top"].items()}
    plans_full["blocks"] = plans["blocks"]

    def norm(diff):
        return jnp.sqrt(tree_sq_norm(diff, plans_full, axis_names))

    return norm
