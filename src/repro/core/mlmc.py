"""MLMC gradient estimation with the DynaBRO fail-safe filter (Alg. 1 & 2).

Per round: sample ``J ~ Geom(1/2)`` (host side — the level picks which
compiled step runs); aggregate worker mini-batch gradients at levels
``0, J-1, J``; combine ``g = ĝ⁰ + 2^J (ĝ^J − ĝ^{J−1})`` guarded by the
fail-safe event

    E_t = { ‖ĝ^J − ĝ^{J−1}‖ ≤ (1+√2) · c_E · C · V / √(2^J) }      (Eq. 6)

with ``C = sqrt(8 log(16 m² T))``; Option 1 sets ``c_E = √γ``
(γ = 2κ_δ + 1/m), Option 2 (MFM) sets ``c_E = 6√2`` (δ-oblivious).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def sample_level(rng: np.random.Generator, j_max: int) -> int:
    """J ~ Geom(1/2) (support 1, 2, ...), truncated at j_max for dispatch."""
    j = int(rng.geometric(0.5))
    return min(j, j_max + 1)  # j_max+1 encodes 'beyond cap' -> correction dropped


def level_schedule(rng: np.random.Generator, j_max: int, T: int) -> np.ndarray:
    """Host-side (T,) MLMC level schedule — the exact per-round sequence the
    Python-loop driver draws, precomputed so the whole loop can run inside one
    ``lax.scan`` (DESIGN.md §5). Entries lie in {1, …, j_max+1}."""
    return np.array([sample_level(rng, j_max) for _ in range(T)], np.int32)


def level_prefix(tree, n_units: int, n_total: int, axis: int = 0):
    """Prefix-slice each leaf to the level-``n_units`` nested sub-batch of an
    ``n_total``-unit batch along ``axis``.

    The MLMC levels are *nested*: the level-(J−1) mini-batch is the first half
    of the level-J mini-batch (DESIGN.md §3), so a level-n gradient reads the
    first ``n/n_total`` prefix of the padded batch. Shared by the Mode B step
    builder (axis 0 of the flattened local batch) and the scan driver's
    ``lax.switch`` branches (axis 1 of the (m, n_max, …) stack)."""
    def sl(x):
        k = x.shape[axis] * n_units // n_total
        idx = [slice(None)] * x.ndim
        idx[axis] = slice(0, k)
        return x[tuple(idx)]
    return jax.tree.map(sl, tree)


def universal_C(m: int, T: int) -> float:
    return math.sqrt(8.0 * math.log(16.0 * m * m * T))


@dataclasses.dataclass(frozen=True)
class MLMCConfig:
    T: int  # total rounds (sets J_max = floor(log2 T) and the C constant)
    m: int  # number of workers
    V: float  # bounded-noise level (Assumption 2.2)
    option: int = 1  # 1: (δ,κ)-robust agg, 2: MFM
    kappa: float = 1.0  # κ_δ of the aggregator (Option 1)
    use_failsafe: bool = True
    j_cap: int = 7  # practical cap (Appendix J uses J_max=7)

    @property
    def j_max(self) -> int:
        return min(int(math.log2(max(self.T, 2))), self.j_cap)

    @property
    def gamma(self) -> float:
        return 2.0 * self.kappa + 1.0 / self.m

    @property
    def c_E(self) -> float:
        if self.option == 2:
            return 6.0 * math.sqrt(2.0)
        return math.sqrt(self.gamma)

    @property
    def threshold_coeff(self) -> float:
        """The j-independent factor (1+√2)·c_E·C·V of the fail-safe bound —
        what the lane-batched sweep carries per lane (aggregator option/c_E
        is per-lane data there, DESIGN.md §7). Kept as one left-associated
        f64 product so the traced path (f32 coeff / √2^j) is bitwise equal
        to ``threshold``."""
        C = universal_C(self.m, self.T)
        return (1.0 + math.sqrt(2.0)) * self.c_E * C * self.V

    def threshold(self, j) -> jax.Array:
        """Fail-safe bound (1+√2)·c_E·C·V/√(2^j)."""
        return self.threshold_coeff / jnp.sqrt(2.0 ** j)

    def mfm_tau(self, n: int) -> float:
        """MFM threshold T^N = 2·C·V/√N (Option 2)."""
        return 2.0 * universal_C(self.m, self.T) * self.V / math.sqrt(n)


def tree_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def mlmc_combine(g0, gjm1, gj, j: int, cfg: MLMCConfig, threshold=None,
                 norm_fn=None):
    """Combine aggregated level gradients into the MLMC estimate.

    g0/gjm1/gj: pytrees (aggregated gradients at batch sizes 1, 2^{j-1}, 2^j).
    ``j`` is static (host-sampled). Returns (g, info dict). ``threshold``
    overrides ``cfg.threshold(j)`` — the lane-batched sweep passes a traced
    per-lane bound there, because lanes mixing MFM with (δ,κ)-robust rules
    differ in the fail-safe constant c_E (DESIGN.md §7). ``norm_fn``
    overrides ``tree_norm`` on the correction — Mode B passes a psum-based
    global norm there, because inside its partial-manual region each device
    only holds a worker-sharded slice of the diff tree."""
    if j > cfg.j_max or gj is None:
        info = {"level": j, "failsafe_ok": jnp.array(True), "corr_norm": jnp.zeros(())}
        return g0, info
    diff = jax.tree.map(lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32), gj, gjm1)
    dn = (norm_fn or tree_norm)(diff)
    if threshold is None:
        threshold = cfg.threshold(j)
    ok = dn <= threshold if cfg.use_failsafe else jnp.array(True)
    scale = jnp.where(ok, 2.0 ** j, 0.0)
    g = jax.tree.map(lambda a, d: (a.astype(jnp.float32) + scale * d).astype(a.dtype),
                     g0, diff)
    info = {"level": j, "failsafe_ok": ok, "corr_norm": dn}
    return g, info


def round_cost(j: int, j_max: int) -> int:
    """Per-worker stochastic-gradient evaluations a level-j round actually
    computes — the one cost-accounting contract shared by the drivers' round
    logs and ``expected_cost`` (DESIGN.md §7).

    In-cap MLMC rounds (1 ≤ j ≤ j_max) evaluate the level-0 unit plus the
    2^{j-1} + 2^j correction mini-batches. Beyond-cap rounds (j > j_max: the
    correction is dropped and each worker computes one unit batch) cost 1,
    exactly like plain-SGD rounds (j = 0)."""
    if 1 <= j <= j_max:
        return 1 + 2 ** (j - 1) + 2 ** j
    return 1


def expected_cost(j: int, j_max: Optional[int] = None) -> int:
    """Per-worker cost of a level-j round; ``j_max=None`` means uncapped
    (every j ≥ 1 is treated as in-cap)."""
    return round_cost(j, j_max if j_max is not None else max(j, 1))
