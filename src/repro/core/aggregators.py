"""Robust aggregation rules, expressed over the ``core.agg_engine`` primitives.

Two call conventions:
  * matrix:  ``agg(x)`` with ``x: (m, d)`` -> ``(d,)``
  * pytree:  ``agg.tree(stacked)`` where every leaf has leading worker axis m.

Coordinate-wise rules (Mean/CWMed/CWTM) apply leaf-by-leaf and are exact in
both conventions. Distance-based rules (Krum/GeoMed/MFM/NNM) need the global
geometry: the tree convention computes *global* pairwise distances by summing
per-leaf contributions, then combines per-leaf — also exact.  No rule
materializes the flat ``(m, d_total)`` matrix: only the tiny ``(m, m)``
statistics are global, everything else streams per leaf (DESIGN.md §4).

Every rule runs on either engine backend — ``ref`` (pure jnp) or ``pallas``
(the repro.kernels TPU kernels; interpret mode on CPU) — selected by the
``backend`` argument of ``get_aggregator`` (``"auto"`` picks per platform).

Each rule is also registered under the engine's uniform traced-theta form
``(stacked, n, theta)`` (DESIGN.md §4, bottom of this file): hyperparameters
become data read from theta slots, which is what lets the lane-batched
scenario sweep dispatch a per-lane aggregation rule — and per-lane
hyperparameters — inside one compiled scan. The class rules and the uniform
forms share the weight/score cores below, so the two paths are bitwise
equal on the ref backend.

``(δ, κ_δ)-robustness`` (Def. 3.2, Allouah et al. 2023) holds for CWMed, CWTM,
Krum and GeoMed (with κ_δ listed in ``KAPPA``); MFM (Alg. 3 of the paper) is
deliberately *not* (δ,κ)-robust (App. F.1) but gives the optimal δ²-scaling
under bounded noise (Lemma 5.1).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agg_engine import (
    GEOMED_MAX_ITERS, Aggregator, CoordinateWiseRule, GeometryRule, Tree,
    _as_mat, agg_param_spec, count_ceil, cw_mean, cw_median,
    cw_trimmed_mean, get_aggregator, register, register_uniform,
    traced_count, traced_trim_count, tree_combine_reduce, tree_cross_sqdist,
    tree_pairwise_sqdist, tree_weighted_combine, trim_count,
)

__all__ = [
    "Aggregator", "Mean", "CWMed", "CWTM", "Krum", "GeoMed", "NNM", "MFM",
    "KAPPA", "get_aggregator", "pairwise_sqdists", "tree_pairwise_sqdists",
    "tree_stack_to_mat", "mat_to_tree",
]


# ---------------------------------------------------------------- helpers
#
# Flat-matrix helpers kept for tests/diagnostics; the rules themselves no
# longer go through tree_stack_to_mat.


def tree_stack_to_mat(stacked: Tree) -> jax.Array:
    """(m, ...)-leaf tree -> (m, d) matrix (diagnostics only — O(m·d) f32)."""
    leaves = jax.tree.leaves(stacked)
    m = leaves[0].shape[0]
    return jnp.concatenate([l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)


def mat_to_tree(vec: jax.Array, like: Tree) -> Tree:
    """(d,) vector -> tree shaped like one worker's entry of `like`."""
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for l in leaves:
        sz = int(jnp.size(l[0]))
        out.append(vec[off:off + sz].reshape(l.shape[1:]).astype(l.dtype))
        off += sz
    return jax.tree.unflatten(treedef, out)


def pairwise_sqdists(x: jax.Array) -> jax.Array:
    """x: (m, d) -> (m, m) squared L2 distances (ref backend)."""
    from repro.core.agg_engine import pairwise_sqdist
    return pairwise_sqdist(x.astype(jnp.float32), backend="ref")


def tree_pairwise_sqdists(stacked: Tree) -> jax.Array:
    """Global (m, m) squared distances summed over all leaves (ref backend)."""
    return tree_pairwise_sqdist(stacked, backend="ref")


# ---------------------------------------------------------------- cores
#
# The weight/score math shared by the class rules (static hyperparameters)
# and the uniform theta forms (traced hyperparameters, DESIGN.md §4). Both
# call the SAME functions — structural counts like trim/k arrive as Python
# ints from one path and int32 scalars from the other, and every core is
# written in the full-width masked style so the op sequence (and hence the
# ref-backend bitstream) is identical either way. A statically-sliced
# variant (``sorted[:, :k].sum(1)``) would reduce over a different tree
# shape and drift at ULP level between the paths.


def _krum_scores(d2: jax.Array, k) -> jax.Array:
    """Sum of each worker's k nearest squared distances (self excluded)."""
    m = d2.shape[0]
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf, jnp.float32))
    srt = jnp.sort(d2, axis=1)
    return jnp.where(jnp.arange(m)[None, :] < k, srt, 0.0).sum(1)


def _krum_weights(d2: jax.Array, k, multi) -> jax.Array:
    """(m,) selection weights: 1/multi on the multi best-scored workers."""
    s = _krum_scores(d2, k)
    m = s.shape[0]
    _, idx = jax.lax.top_k(-s, m)  # stable full argsort by score
    per = jnp.where(jnp.arange(m) < multi, 1.0 / multi, 0.0)
    return jnp.zeros_like(s).at[idx].set(per)


def _nnm_weights(d2: jax.Array, k) -> jax.Array:
    """(m, m) mixing matrix: row i averages worker i's k nearest (incl self)."""
    m = d2.shape[0]
    _, idx = jax.lax.top_k(-d2, m)  # stable full argsort per row
    ws = jnp.where(jnp.arange(m) < k, 1.0 / k, 0.0)
    return jax.vmap(lambda ix: jnp.zeros((m,)).at[ix].set(ws))(idx)


def _mfm_weights(d2: jax.Array, tau) -> jax.Array:
    """Median-Filtered-Mean weights (Alg. 3); all-zero => output 0."""
    m = d2.shape[0]
    d = jnp.sqrt(d2)
    within_half = (d <= tau / 2).sum(1)  # includes self
    is_med_candidate = within_half > m / 2
    any_med = is_med_candidate.any()
    med_idx = jnp.argmax(is_med_candidate)  # first candidate
    close = d[med_idx] <= tau  # (m,)
    w = close.astype(jnp.float32)
    return jnp.where(any_med, w / jnp.maximum(w.sum(), 1.0), jnp.zeros((m,)))


def _geomed_tree(stacked, iters, eps, backend: str, unroll: int):
    """Weiszfeld iterations, unrolled ``unroll`` times with each step gated
    on ``i < iters`` — a no-op gate for the class path (static iters ==
    unroll), the stop condition for the traced path (iters from theta,
    unroll == GEOMED_MAX_ITERS)."""
    static = isinstance(iters, (int, np.integer))
    m = jax.tree.leaves(stacked)[0].shape[0]
    z = tree_weighted_combine(stacked, jnp.full((m,), 1.0 / m, jnp.float32),
                              backend=backend, out_dtype=jnp.float32)
    for i in range(unroll):
        d2 = tree_cross_sqdist(stacked, z, backend=backend)
        w = 1.0 / jnp.sqrt(d2 + eps)
        zn = tree_weighted_combine(stacked, w / w.sum(),
                                   backend=backend, out_dtype=jnp.float32)
        if static:
            z = zn  # every unrolled step is live
        else:
            live = jnp.asarray(i, jnp.float32) < iters
            z = jax.tree.map(lambda a, b: jnp.where(live, a, b), zn, z)
    return jax.tree.map(lambda zl, l: zl.astype(l.dtype), z, stacked)


# ---------------------------------------------------------------- rules


class Mean(CoordinateWiseRule):
    name = "mean"
    cr_mode = "mean"  # combine_reduce mode: NNM fuses mix+reduce for us

    def _reduce(self, mat):
        return cw_mean(mat, backend=self.backend)


class CWMed(CoordinateWiseRule):
    """Coordinate-wise median (Yin et al., 2018)."""
    name = "cwmed"
    cr_mode = "med"

    def _reduce(self, mat):
        return cw_median(mat, backend=self.backend)


class CWTM(CoordinateWiseRule):
    """Coordinate-wise trimmed mean: drop ⌈δm⌉ highest/lowest per coordinate."""
    name = "cwtm"
    cr_mode = "tm"

    def __init__(self, delta: float = 0.25, backend: str = "auto"):
        super().__init__(backend)
        self.delta = delta

    def _reduce(self, mat):
        return cw_trimmed_mean(mat, trim_count(self.delta, mat.shape[0]),
                               backend=self.backend)


class Krum(GeometryRule):
    """(Multi-)Krum (Blanchard et al., 2017): pick the vector(s) with the
    smallest sum of distances to its m - ⌈δm⌉ - 2 nearest neighbours."""
    name = "krum"

    def __init__(self, delta: float = 0.25, multi: int = 1, backend: str = "auto"):
        super().__init__(backend)
        self.delta = delta
        self.multi = multi

    def _k(self, m: int) -> int:
        return max(m - count_ceil(self.delta * m) - 2, 1)

    def scores(self, d2: jax.Array) -> jax.Array:
        return _krum_scores(d2, self._k(d2.shape[0]))

    def _weights(self, d2):
        return _krum_weights(d2, self._k(d2.shape[0]), self.multi)


class GeoMed(Aggregator):
    """Geometric median via Weiszfeld iterations (Pillutla et al., 2022).
    Each iteration is one cross-distance accumulate (x vs the iterate z) plus
    one weighted combine — both streamed per leaf."""
    name = "geomed"

    def __init__(self, iters: int = 8, eps: float = 1e-8, backend: str = "auto"):
        super().__init__(backend)
        self.iters = iters
        self.eps = eps

    def tree(self, stacked):
        return _geomed_tree(stacked, self.iters, self.eps, self.backend,
                            unroll=self.iters)


class NNM(GeometryRule):
    """Nearest-Neighbor Mixing (Allouah et al., 2023): replace each input by
    the mean of its m - ⌈δm⌉ nearest neighbours, then apply a base rule."""
    name = "nnm"

    def __init__(self, base: Aggregator, delta: float = 0.25, backend: str = "auto"):
        super().__init__(backend)
        self.base = base
        self.delta = delta
        self.name = f"nnm+{base.name}"

    def _weights(self, d2: jax.Array) -> jax.Array:
        m = d2.shape[0]
        return _nnm_weights(d2, m - count_ceil(self.delta * m))

    def tree(self, stacked):
        d2 = tree_pairwise_sqdist(stacked, backend=self.backend)
        w = self._weights(d2)
        mode = getattr(self.base, "cr_mode", None)
        if mode is not None:
            # coordinate-wise base: mix+reduce as ONE fused primitive — the
            # (m, d) mixed stack never materializes (agg_engine.combine_reduce)
            trim = trim_count(self.base.delta, d2.shape[0]) if mode == "tm" else 0
            return tree_combine_reduce(stacked, w, mode=mode, trim=trim,
                                       backend=self.backend)
        mixed = tree_weighted_combine(stacked, w, backend=self.backend)
        return self.base.tree(mixed)


class MFM(GeometryRule):
    """Median-Filtered Mean (Alg. 3). Threshold ``tau`` must be supplied per
    call (it scales as 2·C·V/√N with the mini-batch size N)."""
    name = "mfm"

    def __init__(self, tau: Optional[float] = None, backend: str = "auto"):
        super().__init__(backend)
        self.tau = tau

    def __call__(self, x, tau: Optional[float] = None):
        return self.tree(jnp.asarray(x).astype(jnp.float32), tau)

    def tree(self, stacked, tau: Optional[float] = None):
        tau = tau if tau is not None else self.tau
        assert tau is not None, "MFM needs a threshold"
        d2 = tree_pairwise_sqdist(stacked, backend=self.backend)
        return tree_weighted_combine(stacked, _mfm_weights(d2, tau),
                                     backend=self.backend)


# ---------------------------------------------------------------- registry

KAPPA = {
    # κ_δ orders from Allouah et al. (2023), Table 1 (up to constants)
    "mean": lambda d, m: float("inf"),
    "cwmed": lambda d, m: 4 * d / (1 - 2 * d) if d < 0.5 else float("inf"),
    "cwtm": lambda d, m: 6 * d / (1 - 2 * d) * (1 + d / (1 - 2 * d)) if d < 0.5 else float("inf"),
    "krum": lambda d, m: 6 * d / (1 - 2 * d) if d < 0.5 else float("inf"),
    "geomed": lambda d, m: 4 * (1 + d / (1 - 2 * d)) ** 2 if d < 0.5 else float("inf"),
}

register("mean", lambda delta=0.25, tau=None, backend="auto": Mean(backend=backend))
register("cwmed", lambda delta=0.25, tau=None, backend="auto": CWMed(backend=backend))
register("cwtm", lambda delta=0.25, tau=None, backend="auto": CWTM(delta, backend=backend))
register("krum", lambda delta=0.25, tau=None, backend="auto", multi=1:
         Krum(delta, multi=int(multi), backend=backend))
register("geomed", lambda delta=0.25, tau=None, backend="auto", iters=8,
         eps=1e-8: GeoMed(int(iters), eps, backend=backend))
register("mfm", lambda delta=0.25, tau=None, backend="auto": MFM(tau, backend=backend))


# ------------------------------------------------- uniform theta forms
#
# The ``(stacked, n, theta) -> agg_tree`` implementations behind
# ``agg_engine.uniform_aggregator`` / ``agg_switch`` (DESIGN.md §4): the
# lax.switch branch forms of the lane-batched sweep, reading hyperparameters
# from theta slots per ``agg_param_spec``. They call the identical cores as
# the classes above, so on the ref backend a uniform call is bitwise equal
# to ``get_aggregator(name, ...)`` with the same hyperparameters.


def _uniform_cw(reduce_fn):
    """Coordinate-wise uniform form from a (mat, theta, backend) reducer —
    per-leaf reshape/astype exactly as ``CoordinateWiseRule.leaf``."""
    def build(backend, mlmc):
        def fn(stacked, n, theta):
            def leaf(l):
                out = reduce_fn(_as_mat(l), theta, backend)
                return out.reshape(l.shape[1:]).astype(l.dtype)
            return jax.tree.map(leaf, stacked)
        return fn
    return build


def _build_krum(backend, mlmc):
    def fn(stacked, n, theta):
        m = jax.tree.leaves(stacked)[0].shape[0]
        k = jnp.maximum(m - traced_count(theta[0] * m) - 2, 1)
        d2 = tree_pairwise_sqdist(stacked, backend=backend)
        return tree_weighted_combine(stacked, _krum_weights(d2, k, theta[1]),
                                     backend=backend)
    return fn


def _build_geomed(backend, mlmc):
    def fn(stacked, n, theta):
        return _geomed_tree(stacked, theta[0], theta[1], backend,
                            unroll=GEOMED_MAX_ITERS)
    return fn


def _build_mfm(backend, mlmc):
    def fn(stacked, n, theta):
        tau = theta[0]
        if mlmc is not None:  # NaN sentinel -> the Option-2 auto threshold
            tau = jnp.where(jnp.isnan(tau), jnp.float32(mlmc.mfm_tau(n)), tau)
        d2 = tree_pairwise_sqdist(stacked, backend=backend)
        return tree_weighted_combine(stacked, _mfm_weights(d2, tau),
                                     backend=backend)
    return fn


def _build_nnm(base_name, backend, mlmc):
    from repro.core.agg_engine import uniform_aggregator
    base_fn = uniform_aggregator(base_name, backend=backend, mlmc=mlmc)
    merged = [p for p, _ in agg_param_spec("nnm+" + base_name)]
    idx = np.array([merged.index(p) for p, _ in agg_param_spec(base_name)],
                   np.int32)
    # coordinate-wise bases take the fused mix+reduce primitive, mirroring
    # NNM.tree exactly (same ops either path => ref bitstreams stay equal)
    mode = {"mean": "mean", "cwmed": "med", "cwtm": "tm"}.get(base_name)

    def fn(stacked, n, theta):
        m = jax.tree.leaves(stacked)[0].shape[0]
        k = m - traced_count(theta[0] * m)
        d2 = tree_pairwise_sqdist(stacked, backend=backend)
        w = _nnm_weights(d2, k)
        if mode is not None:
            trim = traced_trim_count(theta[0], m) if mode == "tm" else 0
            return tree_combine_reduce(stacked, w, mode=mode, trim=trim,
                                       backend=backend)
        mixed = tree_weighted_combine(stacked, w, backend=backend)
        return base_fn(mixed, n, theta[idx] if idx.size else theta[:0])
    return fn


register_uniform("mean", _uniform_cw(lambda mat, th, b: cw_mean(mat, backend=b)))
register_uniform("cwmed", _uniform_cw(lambda mat, th, b: cw_median(mat, backend=b)))
register_uniform("cwtm", _uniform_cw(
    lambda mat, th, b: cw_trimmed_mean(
        mat, traced_trim_count(th[0], mat.shape[0]), backend=b)))
register_uniform("krum", _build_krum)
register_uniform("geomed", _build_geomed)
register_uniform("mfm", _build_mfm)
register_uniform("nnm", _build_nnm)
