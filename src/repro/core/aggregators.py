"""Robust aggregation rules, expressed over the ``core.agg_engine`` primitives.

Two call conventions:
  * matrix:  ``agg(x)`` with ``x: (m, d)`` -> ``(d,)``
  * pytree:  ``agg.tree(stacked)`` where every leaf has leading worker axis m.

Coordinate-wise rules (Mean/CWMed/CWTM) apply leaf-by-leaf and are exact in
both conventions. Distance-based rules (Krum/GeoMed/MFM/NNM) need the global
geometry: the tree convention computes *global* pairwise distances by summing
per-leaf contributions, then combines per-leaf — also exact.  No rule
materializes the flat ``(m, d_total)`` matrix: only the tiny ``(m, m)``
statistics are global, everything else streams per leaf (DESIGN.md §4).

Every rule runs on either engine backend — ``ref`` (pure jnp) or ``pallas``
(the repro.kernels TPU kernels; interpret mode on CPU) — selected by the
``backend`` argument of ``get_aggregator`` (``"auto"`` picks per platform).

``(δ, κ_δ)-robustness`` (Def. 3.2, Allouah et al. 2023) holds for CWMed, CWTM,
Krum and GeoMed (with κ_δ listed in ``KAPPA``); MFM (Alg. 3 of the paper) is
deliberately *not* (δ,κ)-robust (App. F.1) but gives the optimal δ²-scaling
under bounded noise (Lemma 5.1).
"""
from __future__ import annotations

from typing import Optional

import math

import jax
import jax.numpy as jnp

from repro.core.agg_engine import (
    Aggregator, CoordinateWiseRule, GeometryRule, Tree,
    cw_mean, cw_median, cw_trimmed_mean, get_aggregator, register,
    tree_cross_sqdist, tree_pairwise_sqdist, tree_weighted_combine,
    trim_count,
)

__all__ = [
    "Aggregator", "Mean", "CWMed", "CWTM", "Krum", "GeoMed", "NNM", "MFM",
    "KAPPA", "get_aggregator", "pairwise_sqdists", "tree_pairwise_sqdists",
    "tree_stack_to_mat", "mat_to_tree",
]


# ---------------------------------------------------------------- helpers
#
# Flat-matrix helpers kept for tests/diagnostics; the rules themselves no
# longer go through tree_stack_to_mat.


def tree_stack_to_mat(stacked: Tree) -> jax.Array:
    """(m, ...)-leaf tree -> (m, d) matrix (diagnostics only — O(m·d) f32)."""
    leaves = jax.tree.leaves(stacked)
    m = leaves[0].shape[0]
    return jnp.concatenate([l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)


def mat_to_tree(vec: jax.Array, like: Tree) -> Tree:
    """(d,) vector -> tree shaped like one worker's entry of `like`."""
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for l in leaves:
        sz = int(jnp.size(l[0]))
        out.append(vec[off:off + sz].reshape(l.shape[1:]).astype(l.dtype))
        off += sz
    return jax.tree.unflatten(treedef, out)


def pairwise_sqdists(x: jax.Array) -> jax.Array:
    """x: (m, d) -> (m, m) squared L2 distances (ref backend)."""
    from repro.core.agg_engine import pairwise_sqdist
    return pairwise_sqdist(x.astype(jnp.float32), backend="ref")


def tree_pairwise_sqdists(stacked: Tree) -> jax.Array:
    """Global (m, m) squared distances summed over all leaves (ref backend)."""
    return tree_pairwise_sqdist(stacked, backend="ref")


# ---------------------------------------------------------------- rules


class Mean(CoordinateWiseRule):
    name = "mean"

    def _reduce(self, mat):
        return cw_mean(mat, backend=self.backend)


class CWMed(CoordinateWiseRule):
    """Coordinate-wise median (Yin et al., 2018)."""
    name = "cwmed"

    def _reduce(self, mat):
        return cw_median(mat, backend=self.backend)


class CWTM(CoordinateWiseRule):
    """Coordinate-wise trimmed mean: drop ⌈δm⌉ highest/lowest per coordinate."""
    name = "cwtm"

    def __init__(self, delta: float = 0.25, backend: str = "auto"):
        super().__init__(backend)
        self.delta = delta

    def _reduce(self, mat):
        return cw_trimmed_mean(mat, trim_count(self.delta, mat.shape[0]),
                               backend=self.backend)


class Krum(GeometryRule):
    """(Multi-)Krum (Blanchard et al., 2017): pick the vector(s) with the
    smallest sum of distances to its m - ⌈δm⌉ - 2 nearest neighbours."""
    name = "krum"

    def __init__(self, delta: float = 0.25, multi: int = 1, backend: str = "auto"):
        super().__init__(backend)
        self.delta = delta
        self.multi = multi

    def scores(self, d2: jax.Array) -> jax.Array:
        m = d2.shape[0]
        f = math.ceil(self.delta * m)
        k = max(m - f - 2, 1)
        d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf, jnp.float32))
        nearest = jnp.sort(d2, axis=1)[:, :k]
        return nearest.sum(1)

    def _weights(self, d2):
        s = self.scores(d2)
        if self.multi == 1:
            return jax.nn.one_hot(jnp.argmin(s), s.shape[0])
        _, idx = jax.lax.top_k(-s, self.multi)
        return jnp.zeros_like(s).at[idx].set(1.0 / self.multi)


class GeoMed(Aggregator):
    """Geometric median via Weiszfeld iterations (Pillutla et al., 2022).
    Each iteration is one cross-distance accumulate (x vs the iterate z) plus
    one weighted combine — both streamed per leaf."""
    name = "geomed"

    def __init__(self, iters: int = 8, eps: float = 1e-8, backend: str = "auto"):
        super().__init__(backend)
        self.iters = iters
        self.eps = eps

    def tree(self, stacked):
        m = jax.tree.leaves(stacked)[0].shape[0]
        z = tree_weighted_combine(stacked, jnp.full((m,), 1.0 / m, jnp.float32),
                                  backend=self.backend, out_dtype=jnp.float32)
        for _ in range(self.iters):
            d2 = tree_cross_sqdist(stacked, z, backend=self.backend)
            w = 1.0 / jnp.sqrt(d2 + self.eps)
            z = tree_weighted_combine(stacked, w / w.sum(),
                                      backend=self.backend, out_dtype=jnp.float32)
        return jax.tree.map(lambda zl, l: zl.astype(l.dtype), z, stacked)


class NNM(GeometryRule):
    """Nearest-Neighbor Mixing (Allouah et al., 2023): replace each input by
    the mean of its m - ⌈δm⌉ nearest neighbours, then apply a base rule."""
    name = "nnm"

    def __init__(self, base: Aggregator, delta: float = 0.25, backend: str = "auto"):
        super().__init__(backend)
        self.base = base
        self.delta = delta
        self.name = f"nnm+{base.name}"

    def _weights(self, d2: jax.Array) -> jax.Array:
        m = d2.shape[0]
        f = math.ceil(self.delta * m)
        k = m - f
        _, idx = jax.lax.top_k(-d2, k)  # (m, k) nearest (incl self, d=0)
        w = jax.vmap(lambda ix: jnp.zeros((m,)).at[ix].set(1.0 / k))(idx)
        return w  # (m, m) row i = mixing weights for worker i

    def tree(self, stacked):
        d2 = tree_pairwise_sqdist(stacked, backend=self.backend)
        mixed = tree_weighted_combine(stacked, self._weights(d2),
                                      backend=self.backend)
        return self.base.tree(mixed)


class MFM(GeometryRule):
    """Median-Filtered Mean (Alg. 3). Threshold ``tau`` must be supplied per
    call (it scales as 2·C·V/√N with the mini-batch size N)."""
    name = "mfm"

    def __init__(self, tau: Optional[float] = None, backend: str = "auto"):
        super().__init__(backend)
        self.tau = tau

    def _mfm_weights(self, d2: jax.Array, tau) -> jax.Array:
        m = d2.shape[0]
        d = jnp.sqrt(d2)
        within_half = (d <= tau / 2).sum(1)  # includes self
        is_med_candidate = within_half > m / 2
        any_med = is_med_candidate.any()
        med_idx = jnp.argmax(is_med_candidate)  # first candidate
        close = d[med_idx] <= tau  # (m,)
        w = close.astype(jnp.float32)
        w = jnp.where(any_med, w / jnp.maximum(w.sum(), 1.0), jnp.zeros((m,)))
        return w  # all-zero => output 0 (the algorithm's fallback)

    def __call__(self, x, tau: Optional[float] = None):
        return self.tree(jnp.asarray(x).astype(jnp.float32), tau)

    def tree(self, stacked, tau: Optional[float] = None):
        tau = tau if tau is not None else self.tau
        assert tau is not None, "MFM needs a threshold"
        d2 = tree_pairwise_sqdist(stacked, backend=self.backend)
        return tree_weighted_combine(stacked, self._mfm_weights(d2, tau),
                                     backend=self.backend)


# ---------------------------------------------------------------- registry

KAPPA = {
    # κ_δ orders from Allouah et al. (2023), Table 1 (up to constants)
    "mean": lambda d, m: float("inf"),
    "cwmed": lambda d, m: 4 * d / (1 - 2 * d) if d < 0.5 else float("inf"),
    "cwtm": lambda d, m: 6 * d / (1 - 2 * d) * (1 + d / (1 - 2 * d)) if d < 0.5 else float("inf"),
    "krum": lambda d, m: 6 * d / (1 - 2 * d) if d < 0.5 else float("inf"),
    "geomed": lambda d, m: 4 * (1 + d / (1 - 2 * d)) ** 2 if d < 0.5 else float("inf"),
}

register("mean", lambda delta=0.25, tau=None, backend="auto": Mean(backend=backend))
register("cwmed", lambda delta=0.25, tau=None, backend="auto": CWMed(backend=backend))
register("cwtm", lambda delta=0.25, tau=None, backend="auto": CWTM(delta, backend=backend))
register("krum", lambda delta=0.25, tau=None, backend="auto": Krum(delta, backend=backend))
register("geomed", lambda delta=0.25, tau=None, backend="auto": GeoMed(backend=backend))
register("mfm", lambda delta=0.25, tau=None, backend="auto": MFM(tau, backend=backend))
