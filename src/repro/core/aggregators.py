"""Robust aggregation rules.

Two call conventions:
  * matrix:  ``agg(x)`` with ``x: (m, d)`` -> ``(d,)``
  * pytree:  ``agg.tree(stacked)`` where every leaf has leading worker axis m.

Coordinate-wise rules (Mean/CWMed/CWTM) apply leaf-by-leaf and are exact in
both conventions. Distance-based rules (Krum/GeoMed/MFM/NNM) need the global
geometry: the tree convention computes *global* pairwise distances by summing
per-leaf contributions, then combines per-leaf — also exact.

``(δ, κ_δ)-robustness`` (Def. 3.2, Allouah et al. 2023) holds for CWMed, CWTM,
Krum and GeoMed (with κ_δ listed in ``KAPPA``); MFM (Alg. 3 of the paper) is
deliberately *not* (δ,κ)-robust (App. F.1) but gives the optimal δ²-scaling
under bounded noise (Lemma 5.1).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import math

import jax
import jax.numpy as jnp

Tree = object


# ---------------------------------------------------------------- helpers


def tree_stack_to_mat(stacked: Tree) -> jax.Array:
    """(m, ...)-leaf tree -> (m, d) matrix."""
    leaves = jax.tree.leaves(stacked)
    m = leaves[0].shape[0]
    return jnp.concatenate([l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)


def mat_to_tree(vec: jax.Array, like: Tree) -> Tree:
    """(d,) vector -> tree shaped like one worker's entry of `like`."""
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for l in leaves:
        sz = int(jnp.size(l[0]))
        out.append(vec[off:off + sz].reshape(l.shape[1:]).astype(l.dtype))
        off += sz
    return jax.tree.unflatten(treedef, out)


def pairwise_sqdists(x: jax.Array) -> jax.Array:
    """x: (m, d) -> (m, m) squared L2 distances."""
    sq = jnp.sum(x * x, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return jnp.maximum(d2, 0.0)


def tree_pairwise_sqdists(stacked: Tree) -> jax.Array:
    """Global (m, m) squared distances summed over all leaves."""
    def leaf_d2(l):
        m = l.shape[0]
        return pairwise_sqdists(l.reshape(m, -1).astype(jnp.float32))
    return sum(jax.tree.leaves(jax.tree.map(leaf_d2, stacked)))


def _tree_weighted_mean(stacked: Tree, w: jax.Array) -> Tree:
    """Per-worker weights w: (m,), sum need not be 1 (caller normalizes)."""
    def leaf(l):
        wl = w.reshape((-1,) + (1,) * (l.ndim - 1)).astype(jnp.float32)
        return (l.astype(jnp.float32) * wl).sum(0).astype(l.dtype)
    return jax.tree.map(leaf, stacked)


# ---------------------------------------------------------------- rules


class Aggregator:
    """Base: subclasses implement __call__ on (m, d) and tree() on stacked trees."""

    name = "base"
    coordinate_wise = False

    def __call__(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def tree(self, stacked: Tree) -> Tree:
        if self.coordinate_wise:
            return jax.tree.map(lambda l: self._leaf(l), stacked)
        # exact global-geometry path
        mat = tree_stack_to_mat(stacked)
        return mat_to_tree(self(mat), stacked)

    def _leaf(self, l: jax.Array) -> jax.Array:
        m = l.shape[0]
        return self(l.reshape(m, -1)).reshape(l.shape[1:]).astype(l.dtype)


class Mean(Aggregator):
    name = "mean"
    coordinate_wise = True

    def __call__(self, x):
        return jnp.mean(x, axis=0)


class CWMed(Aggregator):
    """Coordinate-wise median (Yin et al., 2018)."""
    name = "cwmed"
    coordinate_wise = True

    def __call__(self, x):
        return jnp.median(x.astype(jnp.float32), axis=0)


class CWTM(Aggregator):
    """Coordinate-wise trimmed mean: drop ⌈δm⌉ highest/lowest per coordinate."""
    name = "cwtm"
    coordinate_wise = True

    def __init__(self, delta: float = 0.25):
        self.delta = delta

    def __call__(self, x):
        m = x.shape[0]
        t = min(math.ceil(self.delta * m), (m - 1) // 2)
        xs = jnp.sort(x.astype(jnp.float32), axis=0)
        if t == 0:
            return xs.mean(0)
        return xs[t:m - t].mean(0)


class Krum(Aggregator):
    """(Multi-)Krum (Blanchard et al., 2017): pick the vector(s) with the
    smallest sum of distances to its m - ⌈δm⌉ - 2 nearest neighbours."""
    name = "krum"

    def __init__(self, delta: float = 0.25, multi: int = 1):
        self.delta = delta
        self.multi = multi

    def scores(self, d2: jax.Array) -> jax.Array:
        m = d2.shape[0]
        f = math.ceil(self.delta * m)
        k = max(m - f - 2, 1)
        d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf, jnp.float32))
        nearest = jnp.sort(d2, axis=1)[:, :k]
        return nearest.sum(1)

    def __call__(self, x):
        s = self.scores(pairwise_sqdists(x))
        if self.multi == 1:
            return x[jnp.argmin(s)]
        _, idx = jax.lax.top_k(-s, self.multi)
        return x[idx].mean(0)

    def tree(self, stacked):
        s = self.scores(tree_pairwise_sqdists(stacked))
        if self.multi == 1:
            w = jax.nn.one_hot(jnp.argmin(s), s.shape[0])
        else:
            _, idx = jax.lax.top_k(-s, self.multi)
            w = jnp.zeros_like(s).at[idx].set(1.0 / self.multi)
        return _tree_weighted_mean(stacked, w)


class GeoMed(Aggregator):
    """Geometric median via Weiszfeld iterations (Pillutla et al., 2022)."""
    name = "geomed"

    def __init__(self, iters: int = 8, eps: float = 1e-8):
        self.iters = iters
        self.eps = eps

    def __call__(self, x):
        x = x.astype(jnp.float32)

        def body(_, z):
            d = jnp.sqrt(jnp.sum((x - z[None]) ** 2, axis=1) + self.eps)
            w = 1.0 / d
            return (w[:, None] * x).sum(0) / w.sum()

        return jax.lax.fori_loop(0, self.iters, body, x.mean(0))

    def tree(self, stacked):
        # Weiszfeld on the tree: weights from global distances each iteration
        def dist_to(z):
            def leaf_d2(l, zl):
                m = l.shape[0]
                dl = l.astype(jnp.float32).reshape(m, -1) - zl.astype(jnp.float32).reshape(1, -1)
                return jnp.sum(dl * dl, axis=1)
            return sum(jax.tree.leaves(jax.tree.map(leaf_d2, stacked, z)))

        z = jax.tree.map(lambda l: l.astype(jnp.float32).mean(0), stacked)
        for _ in range(self.iters):
            w = 1.0 / jnp.sqrt(dist_to(z) + self.eps)
            wn = w / w.sum()
            z = _tree_weighted_mean(stacked, wn)
            z = jax.tree.map(lambda l: l.astype(jnp.float32), z)
        like = jax.tree.map(lambda l: l, stacked)
        return jax.tree.map(lambda zl, l: zl.astype(l.dtype), z, like)


class NNM(Aggregator):
    """Nearest-Neighbor Mixing (Allouah et al., 2023): replace each input by
    the mean of its m - ⌈δm⌉ nearest neighbours, then apply a base rule."""
    name = "nnm"

    def __init__(self, base: Aggregator, delta: float = 0.25):
        self.base = base
        self.delta = delta
        self.name = f"nnm+{base.name}"

    def _mix_weights(self, d2: jax.Array) -> jax.Array:
        m = d2.shape[0]
        f = math.ceil(self.delta * m)
        k = m - f
        _, idx = jax.lax.top_k(-d2, k)  # (m, k) nearest (incl self, d=0)
        w = jax.vmap(lambda ix: jnp.zeros((m,)).at[ix].set(1.0 / k))(idx)
        return w  # (m, m) row i = mixing weights for worker i

    def __call__(self, x):
        w = self._mix_weights(pairwise_sqdists(x))
        return self.base(w @ x.astype(jnp.float32))

    def tree(self, stacked):
        w = self._mix_weights(tree_pairwise_sqdists(stacked))
        mixed = jax.tree.map(
            lambda l: jnp.einsum("ij,j...->i...", w,
                                 l.astype(jnp.float32)).astype(l.dtype), stacked)
        return self.base.tree(mixed)


class MFM(Aggregator):
    """Median-Filtered Mean (Alg. 3). Threshold ``tau`` must be supplied per
    call (it scales as 2·C·V/√N with the mini-batch size N)."""
    name = "mfm"

    def __init__(self, tau: Optional[float] = None):
        self.tau = tau

    def _weights(self, d2: jax.Array, tau) -> jax.Array:
        m = d2.shape[0]
        d = jnp.sqrt(d2)
        within_half = (d <= tau / 2).sum(1)  # includes self
        is_med_candidate = within_half > m / 2
        any_med = is_med_candidate.any()
        med_idx = jnp.argmax(is_med_candidate)  # first candidate
        close = d[med_idx] <= tau  # (m,)
        w = close.astype(jnp.float32)
        w = jnp.where(any_med, w / jnp.maximum(w.sum(), 1.0), jnp.zeros((m,)))
        return w  # all-zero => output 0 (the algorithm's fallback)

    def __call__(self, x, tau: Optional[float] = None):
        tau = tau if tau is not None else self.tau
        assert tau is not None, "MFM needs a threshold"
        w = self._weights(pairwise_sqdists(x), tau)
        return (w[:, None] * x.astype(jnp.float32)).sum(0)

    def tree(self, stacked, tau: Optional[float] = None):
        tau = tau if tau is not None else self.tau
        assert tau is not None, "MFM needs a threshold"
        w = self._weights(tree_pairwise_sqdists(stacked), tau)
        return _tree_weighted_mean(stacked, w)


# ---------------------------------------------------------------- registry

KAPPA = {
    # κ_δ orders from Allouah et al. (2023), Table 1 (up to constants)
    "mean": lambda d, m: float("inf"),
    "cwmed": lambda d, m: 4 * d / (1 - 2 * d) if d < 0.5 else float("inf"),
    "cwtm": lambda d, m: 6 * d / (1 - 2 * d) * (1 + d / (1 - 2 * d)) if d < 0.5 else float("inf"),
    "krum": lambda d, m: 6 * d / (1 - 2 * d) if d < 0.5 else float("inf"),
    "geomed": lambda d, m: 4 * (1 + d / (1 - 2 * d)) ** 2 if d < 0.5 else float("inf"),
}


def get_aggregator(name: str, delta: float = 0.25, tau: Optional[float] = None) -> Aggregator:
    name = name.lower()
    if name.startswith("nnm+"):
        return NNM(get_aggregator(name[4:], delta, tau), delta)
    return {
        "mean": Mean,
        "cwmed": CWMed,
        "cwtm": functools.partial(CWTM, delta),
        "krum": functools.partial(Krum, delta),
        "geomed": GeoMed,
        "mfm": functools.partial(MFM, tau),
    }[name]()
