"""Backend-dispatching aggregation engine (DESIGN.md §4).

Every robust aggregation rule in this repo — Mean / CWMed / CWTM / Krum /
GeoMed / NNM / MFM — decomposes into three primitives:

  1. **coordinate-wise reduce**: ``(m, d) -> (d,)`` median / trimmed mean,
  2. **pairwise-distance accumulate**: per-leaf ``(m, d)`` contributions
     summed into global ``(m, m)`` (or ``(m, k)`` cross) squared distances,
  3. **weighted-combine**: ``(k, m) @ (m, d) -> (k, d)`` applied per leaf.

Each primitive has two backends: ``ref`` (pure jnp) and ``pallas`` (the
kernels under ``repro.kernels``, interpret-mode on CPU, compiled on TPU).
``backend="auto"`` picks per platform: pallas on TPU, ref elsewhere.

The crucial consequence for gradient pytrees: only the ``(m, m)`` distance
statistics are global.  Rules therefore *stream leaf by leaf* through the
primitives — pairwise distances sum per-leaf contributions and the combine is
per-leaf too — so no rule ever materializes the full ``(m, d_total)`` float32
matrix that ``tree_stack_to_mat`` used to build.

Both training modes dispatch here: Mode A (`core.robust_train`) through
``get_aggregator(...).tree``, Mode B (`core.sharded`) through
``get_aggregator(...).leaf`` on its post-all-to-all ``(m, shard)`` stacks.
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref

Tree = object

BACKENDS = ("ref", "pallas")


def resolve_backend(backend: str) -> str:
    """'auto' -> 'pallas' on TPU (compiled), 'ref' elsewhere. Explicit
    'pallas' off-TPU runs the same kernel bodies in interpret mode."""
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of "
                         f"{BACKENDS + ('auto',)}")
    return backend


# ============================================================ primitives
#
# All matrix primitives take x: (m, d) and return float32.


def cw_mean(x: jax.Array, *, backend: str = "auto") -> jax.Array:
    """(m, d) -> (d,) mean. Pallas path: uniform-weight combine kernel."""
    if resolve_backend(backend) == "pallas":
        m = x.shape[0]
        w = jnp.full((1, m), 1.0 / m, jnp.float32)
        return kops.weighted_combine_op(x, w)[0]
    return jnp.mean(x.astype(jnp.float32), axis=0)


def cw_median(x: jax.Array, *, backend: str = "auto") -> jax.Array:
    """(m, d) -> (d,) coordinate-wise median."""
    if resolve_backend(backend) == "pallas":
        return kops.cwmed_op(x)
    return kref.cwmed_ref(x)


def cw_trimmed_mean(x: jax.Array, trim: int, *, backend: str = "auto") -> jax.Array:
    """(m, d) -> (d,) mean after dropping `trim` lowest/highest per coord."""
    if trim == 0:
        return cw_mean(x, backend=backend)
    if resolve_backend(backend) == "pallas":
        return kops.cwtm_op(x, trim)
    return kref.cwtm_ref(x, trim)


def pairwise_sqdist(x: jax.Array, *, backend: str = "auto") -> jax.Array:
    """(m, d) -> (m, m) squared L2 distances."""
    if resolve_backend(backend) == "pallas":
        return kops.pairwise_sqdist_op(x)
    return kref.pairwise_sqdist_ref(x)


def cross_sqdist(x: jax.Array, y: jax.Array, *, backend: str = "auto") -> jax.Array:
    """(m, d), (k, d) -> (m, k) squared L2 distances."""
    if resolve_backend(backend) == "pallas":
        return kops.cross_sqdist_op(x, y)
    return kref.cross_sqdist_ref(x, y)


def weighted_combine(x: jax.Array, w: jax.Array, *, backend: str = "auto") -> jax.Array:
    """(m, d) rows combined with weights w: (k, m) -> (k, d), or (m,) -> (d,)."""
    w2 = w[None] if w.ndim == 1 else w
    if resolve_backend(backend) == "pallas":
        out = kops.weighted_combine_op(x, w2)
    else:
        out = kref.weighted_combine_ref(x, w2)
    return out[0] if w.ndim == 1 else out


# ------------------------------------------------------------ tree forms
#
# Leaves carry a leading worker axis m; primitives stream per leaf.


def _as_mat(l: jax.Array) -> jax.Array:
    return l.reshape(l.shape[0], -1).astype(jnp.float32)


def tree_pairwise_sqdist(stacked: Tree, *, backend: str = "auto") -> jax.Array:
    """Global (m, m) squared distances summed over per-leaf contributions."""
    parts = [pairwise_sqdist(_as_mat(l), backend=backend)
             for l in jax.tree.leaves(stacked)]
    return jnp.maximum(sum(parts), 0.0)


def tree_cross_sqdist(stacked: Tree, z: Tree, *, backend: str = "auto") -> jax.Array:
    """Global (m,) squared distances from the m stacked entries to point z
    (a tree shaped like one worker's entry), summed per leaf."""
    zl = jax.tree.leaves(z)
    parts = [cross_sqdist(_as_mat(l), zl[i].reshape(1, -1).astype(jnp.float32),
                          backend=backend)[:, 0]
             for i, l in enumerate(jax.tree.leaves(stacked))]
    return jnp.maximum(sum(parts), 0.0)


def tree_weighted_combine(stacked: Tree, w: jax.Array, *, backend: str = "auto",
                          out_dtype: Optional[object] = None) -> Tree:
    """Per-leaf weighted combine.

    w: (m,)  -> tree shaped like one worker's entry (the aggregate);
    w: (m, m)-> tree with the worker axis kept (each row re-mixed).
    ``out_dtype=None`` keeps each leaf's dtype; pass e.g. jnp.float32 to
    keep full precision across Weiszfeld iterations."""
    def leaf(l):
        out = weighted_combine(_as_mat(l), w, backend=backend)
        shape = l.shape if w.ndim == 2 else l.shape[1:]
        return out.reshape(shape).astype(out_dtype or l.dtype)
    return jax.tree.map(leaf, stacked)


# ============================================================ rule bases


class Aggregator:
    """Base: ``__call__`` on (m, d) matrices, ``.tree()`` on worker-stacked
    pytrees. Both conventions run through the same per-leaf primitives (a
    matrix is just a one-leaf tree), so they agree by construction."""

    name = "base"
    coordinate_wise = False

    def __init__(self, backend: str = "auto"):
        self.backend = backend

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.tree(jnp.asarray(x).astype(jnp.float32))

    def tree(self, stacked: Tree) -> Tree:
        raise NotImplementedError

    def leaf(self, l: jax.Array) -> jax.Array:
        """(m, ...) -> (...). Exact only for coordinate-wise rules — this is
        the Mode B entry point, applied independently per parameter shard."""
        raise NotImplementedError(
            f"{self.name} needs global geometry; only coordinate-wise rules "
            "support per-shard aggregation (DESIGN.md §3)")


class CoordinateWiseRule(Aggregator):
    """Rules that reduce each coordinate independently (exact per-leaf and
    per-shard: Mean / CWMed / CWTM)."""

    coordinate_wise = True

    def _reduce(self, mat: jax.Array) -> jax.Array:  # (m, d) f32 -> (d,) f32
        raise NotImplementedError

    def leaf(self, l: jax.Array) -> jax.Array:
        out = self._reduce(_as_mat(l))
        return out.reshape(l.shape[1:]).astype(l.dtype)

    def tree(self, stacked: Tree) -> Tree:
        return jax.tree.map(self.leaf, stacked)


class GeometryRule(Aggregator):
    """Rules driven by global pairwise geometry: the (m, m) statistics are
    computed once from summed per-leaf contributions, turned into per-worker
    weights, and applied per leaf by the combine primitive."""

    def _weights(self, d2: jax.Array) -> jax.Array:  # (m, m) -> (m,)|(m, m)
        raise NotImplementedError

    def tree(self, stacked: Tree) -> Tree:
        d2 = tree_pairwise_sqdist(stacked, backend=self.backend)
        return tree_weighted_combine(stacked, self._weights(d2),
                                     backend=self.backend)


# ============================================================ registry

_REGISTRY: Dict[str, Callable[..., Aggregator]] = {}


def register(name: str, factory: Callable[..., Aggregator]) -> None:
    _REGISTRY[name] = factory


def registered_rules():
    """Names registered by ``repro.core.aggregators`` (composites like
    ``nnm+<base>`` are resolved dynamically and not listed)."""
    import repro.core.aggregators  # noqa: F401  (registers the rules)
    return tuple(sorted(_REGISTRY))


def get_aggregator(name: str, delta: float = 0.25, tau: Optional[float] = None,
                   backend: str = "auto") -> Aggregator:
    """One registry for both training modes: Mode A consumes ``.tree()``,
    Mode B consumes ``.leaf()`` (coordinate-wise rules only).

    Instances are memoized per (name, delta, tau, backend): rules are
    stateless after construction, and the compiled drivers resolve the rule
    inside every traced ``lax.switch`` branch of every vmapped sweep lane
    (DESIGN.md §5, §7) — caching keeps that a dict hit instead of a
    re-registration import + object build per trace site."""
    return _cached_rule(name.lower(), delta, tau, backend)


@functools.lru_cache(maxsize=None)
def _cached_rule(name: str, delta: float, tau: Optional[float],
                 backend: str) -> Aggregator:
    import repro.core.aggregators as _rules  # registers on first import
    if name.startswith("nnm+"):
        return _rules.NNM(get_aggregator(name[4:], delta, tau, backend),
                          delta, backend=backend)
    if name not in _REGISTRY:
        raise ValueError(f"unknown aggregator {name!r}; known: "
                         f"{registered_rules()} and nnm+<base>")
    return _REGISTRY[name](delta=delta, tau=tau, backend=backend)


def trim_count(delta: float, m: int) -> int:
    """⌈δm⌉ clipped to keep at least one row after two-sided trimming."""
    return min(math.ceil(delta * m), (m - 1) // 2)
