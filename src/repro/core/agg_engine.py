"""Backend-dispatching aggregation engine (DESIGN.md §4).

Every robust aggregation rule in this repo — Mean / CWMed / CWTM / Krum /
GeoMed / NNM / MFM — decomposes into three primitives:

  1. **coordinate-wise reduce**: ``(m, d) -> (d,)`` median / trimmed mean,
  2. **pairwise-distance accumulate**: per-leaf ``(m, d)`` contributions
     summed into global ``(m, m)`` (or ``(m, k)`` cross) squared distances,
  3. **weighted-combine**: ``(k, m) @ (m, d) -> (k, d)`` applied per leaf.

Each primitive has two backends: ``ref`` (pure jnp) and ``pallas`` (the
kernels under ``repro.kernels``, interpret-mode on CPU, compiled on TPU).
``backend="auto"`` dispatches per call site on platform, primitive kind and
bytes moved (``dispatch_backend``): below ``PALLAS_MIN_BYTES`` the kernel
launch overhead dominates and every call goes ref; above it, TPU always
takes the kernels, while CPU takes them only for sort-based reduces (the
bitonic network beats ``jnp.sort`` even interpreted — BENCH_cpu.json)
and leaves matmul-shaped work to BLAS.

The crucial consequence for gradient pytrees: only the ``(m, m)`` distance
statistics are global.  Rules therefore *stream leaf by leaf* through the
primitives — pairwise distances sum per-leaf contributions and the combine is
per-leaf too — so no rule ever materializes the full ``(m, d_total)`` float32
matrix that ``tree_stack_to_mat`` used to build.

Both training modes dispatch here: Mode A (`core.robust_train`) through
``get_aggregator(...).tree``, Mode B (`core.sharded`) through
``get_aggregator(...).leaf`` on its post-all-to-all ``(m, shard)`` stacks.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref

Tree = object

BACKENDS = ("ref", "pallas")


def resolve_backend(backend: str) -> str:
    """'auto' -> 'pallas' on TPU (compiled), 'ref' elsewhere. Explicit
    'pallas' off-TPU runs the same kernel bodies in interpret mode."""
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of "
                         f"{BACKENDS + ('auto',)}")
    return backend


# Below this many bytes of worker stack, one kernel dispatch costs more than
# the whole ref computation (launch + interpret overhead on CPU, launch alone
# on TPU), so ``auto`` falls back to ref. 1 MiB ≈ m=16 × d=16k × f32; the
# bench grid (m=16, d=2¹⁶ → 4.2 MiB) sits above it, the unit-test and
# quadratic-task shapes sit below.
PALLAS_MIN_BYTES = 1 << 20

_DISPATCH_KINDS = ("sort", "matmul")


def dispatch_backend(backend: str, *, kind: str, nbytes: int) -> str:
    """Per-call backend choice for one primitive. Explicit backends are
    honoured as before (``resolve_backend``); ``auto`` picks by size and
    primitive kind: ref below ``PALLAS_MIN_BYTES``; above it, pallas on TPU
    for everything, and on CPU only for ``kind="sort"`` primitives (the
    bitonic-network reduces, where the interpreted kernel still beats
    ``jnp.sort``-based refs) — ``kind="matmul"`` primitives stay on BLAS,
    which an interpreted MXU kernel cannot beat. This is what fixes the
    pairwise/combine kernel rows losing to ref in BENCH_cpu.json: those
    shapes now never reach the interpreted kernel on the auto path."""
    if backend != "auto":
        return resolve_backend(backend)
    if kind not in _DISPATCH_KINDS:
        raise ValueError(f"unknown dispatch kind {kind!r}; want one of "
                         f"{_DISPATCH_KINDS}")
    if nbytes < PALLAS_MIN_BYTES:
        return "ref"
    if jax.default_backend() == "tpu":
        return "pallas"
    return "pallas" if kind == "sort" else "ref"


# ============================================================ primitives
#
# All matrix primitives take x: (m, d) and return float32.


def cw_mean(x: jax.Array, *, backend: str = "auto") -> jax.Array:
    """(m, d) -> (d,) mean. Pallas path: uniform-weight combine kernel."""
    if dispatch_backend(backend, kind="matmul", nbytes=4 * x.size) == "pallas":
        m = x.shape[0]
        w = jnp.full((1, m), 1.0 / m, jnp.float32)
        return kops.weighted_combine_op(x, w)[0]
    return jnp.mean(x.astype(jnp.float32), axis=0)


def cw_median(x: jax.Array, *, backend: str = "auto") -> jax.Array:
    """(m, d) -> (d,) coordinate-wise median."""
    if dispatch_backend(backend, kind="sort", nbytes=4 * x.size) == "pallas":
        return kops.cwmed_op(x)
    return kref.cwmed_ref(x)


def cw_trimmed_mean(x: jax.Array, trim, *, backend: str = "auto") -> jax.Array:
    """(m, d) -> (d,) mean after dropping `trim` lowest/highest per coord.

    ``trim`` may be a Python int (the class-rule path) or a traced int32
    scalar (the uniform theta path, DESIGN.md §4). The ref backend runs one
    masked sorted-sum form for both, so static and traced calls with the same
    trim are bitwise identical; the pallas backend picks the statically-sliced
    kernel when it can and the masked-kernel variant otherwise."""
    if dispatch_backend(backend, kind="sort", nbytes=4 * x.size) == "pallas":
        if isinstance(trim, (int, np.integer)):
            return kops.cwtm_op(x, int(trim))
        return kops.cwtm_masked_op(x, trim)
    return kref.cwtm_ref(x, trim)


def pairwise_sqdist(x: jax.Array, *, backend: str = "auto") -> jax.Array:
    """(m, d) -> (m, m) squared L2 distances."""
    if dispatch_backend(backend, kind="matmul", nbytes=4 * x.size) == "pallas":
        return kops.pairwise_sqdist_op(x)
    return kref.pairwise_sqdist_ref(x)


def cross_sqdist(x: jax.Array, y: jax.Array, *, backend: str = "auto") -> jax.Array:
    """(m, d), (k, d) -> (m, k) squared L2 distances."""
    if dispatch_backend(backend, kind="matmul", nbytes=4 * x.size) == "pallas":
        return kops.cross_sqdist_op(x, y)
    return kref.cross_sqdist_ref(x, y)


def weighted_combine(x: jax.Array, w: jax.Array, *, backend: str = "auto") -> jax.Array:
    """(m, d) rows combined with weights w: (k, m) -> (k, d), or (m,) -> (d,)."""
    w2 = w[None] if w.ndim == 1 else w
    if dispatch_backend(backend, kind="matmul", nbytes=4 * x.size) == "pallas":
        out = kops.weighted_combine_op(x, w2)
    else:
        out = kref.weighted_combine_ref(x, w2)
    return out[0] if w.ndim == 1 else out


def combine_reduce(x: jax.Array, w: jax.Array, mode: str, trim=0, *,
                   backend: str = "auto") -> jax.Array:
    """Mix-then-reduce in one primitive: rows of ``w @ x`` (w: (k, m),
    x: (m, d)) reduced coordinate-wise to (d,) by ``mode`` ∈ {"med", "tm",
    "mean"} — the hot step of NNM composites with a coordinate-wise base.
    The pallas path is ONE fused kernel dispatch: the stack is streamed
    once and the mixed (k, d) matrix never exists in HBM. The ref fallback
    runs the exact two-step the separate primitives would (combine ref,
    then the same reduce refs ``cw_median``/``cw_trimmed_mean``/``cw_mean``
    use), so class and uniform rules routed through here stay bitwise
    identical to each other on ref. ``trim`` (for "tm") may be a Python int
    or a traced int32 count, exactly as in ``cw_trimmed_mean``."""
    kind = "sort" if mode in ("med", "tm") else "matmul"
    if dispatch_backend(backend, kind=kind, nbytes=4 * x.size) == "pallas":
        if mode == "tm" and not isinstance(trim, (int, np.integer)):
            return kops.fused_op(x, w, trim_arr=trim, reduce=mode)["reduce"]
        return kops.fused_op(x, w, reduce=mode,
                             trim=int(trim) if mode == "tm" else 0)["reduce"]
    mixed = kref.weighted_combine_ref(x, w)
    if mode == "med":
        return kref.cwmed_ref(mixed)
    if mode == "tm":
        return kref.cwtm_ref(mixed, trim)
    if mode != "mean":
        raise ValueError(f"unknown combine_reduce mode {mode!r}")
    return jnp.mean(mixed, axis=0)


# ------------------------------------------------------------ tree forms
#
# Leaves carry a leading worker axis m; primitives stream per leaf.


def _as_mat(l: jax.Array) -> jax.Array:
    return l.reshape(l.shape[0], -1).astype(jnp.float32)


def tree_pairwise_sqdist(stacked: Tree, *, backend: str = "auto") -> jax.Array:
    """Global (m, m) squared distances summed over per-leaf contributions."""
    parts = [pairwise_sqdist(_as_mat(l), backend=backend)
             for l in jax.tree.leaves(stacked)]
    return jnp.maximum(sum(parts), 0.0)


def tree_cross_sqdist(stacked: Tree, z: Tree, *, backend: str = "auto") -> jax.Array:
    """Global (m,) squared distances from the m stacked entries to point z
    (a tree shaped like one worker's entry), summed per leaf."""
    zl = jax.tree.leaves(z)
    parts = [cross_sqdist(_as_mat(l), zl[i].reshape(1, -1).astype(jnp.float32),
                          backend=backend)[:, 0]
             for i, l in enumerate(jax.tree.leaves(stacked))]
    return jnp.maximum(sum(parts), 0.0)


def tree_weighted_combine(stacked: Tree, w: jax.Array, *, backend: str = "auto",
                          out_dtype: Optional[object] = None) -> Tree:
    """Per-leaf weighted combine.

    w: (m,)  -> tree shaped like one worker's entry (the aggregate);
    w: (m, m)-> tree with the worker axis kept (each row re-mixed).
    ``out_dtype=None`` keeps each leaf's dtype; pass e.g. jnp.float32 to
    keep full precision across Weiszfeld iterations."""
    def leaf(l):
        out = weighted_combine(_as_mat(l), w, backend=backend)
        shape = l.shape if w.ndim == 2 else l.shape[1:]
        return out.reshape(shape).astype(out_dtype or l.dtype)
    return jax.tree.map(leaf, stacked)


def tree_combine_reduce(stacked: Tree, w: jax.Array, *, mode: str, trim=0,
                        backend: str = "auto") -> Tree:
    """Per-leaf ``combine_reduce``: mix the m worker rows with w (k, m) and
    coordinate-wise reduce the result, returning a tree shaped like one
    worker's entry. One fused kernel dispatch per leaf on the pallas path —
    NNM with a coordinate-wise base goes pairwise -> weights -> THIS,
    instead of a combine pass that materializes the mixed stack followed by
    a reduce pass that re-reads it (DESIGN.md §7)."""
    def leaf(l):
        out = combine_reduce(_as_mat(l), w, mode, trim, backend=backend)
        return out.reshape(l.shape[1:]).astype(l.dtype)
    return jax.tree.map(leaf, stacked)


# ============================================================ rule bases


class Aggregator:
    """Base: ``__call__`` on (m, d) matrices, ``.tree()`` on worker-stacked
    pytrees. Both conventions run through the same per-leaf primitives (a
    matrix is just a one-leaf tree), so they agree by construction."""

    name = "base"
    coordinate_wise = False

    def __init__(self, backend: str = "auto"):
        self.backend = backend

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.tree(jnp.asarray(x).astype(jnp.float32))

    def tree(self, stacked: Tree) -> Tree:
        raise NotImplementedError

    def leaf(self, l: jax.Array) -> jax.Array:
        """(m, ...) -> (...). Exact only for coordinate-wise rules — this is
        the Mode B entry point, applied independently per parameter shard."""
        raise NotImplementedError(
            f"{self.name} needs global geometry; only coordinate-wise rules "
            "support per-shard aggregation (DESIGN.md §3)")


class CoordinateWiseRule(Aggregator):
    """Rules that reduce each coordinate independently (exact per-leaf and
    per-shard: Mean / CWMed / CWTM)."""

    coordinate_wise = True

    def _reduce(self, mat: jax.Array) -> jax.Array:  # (m, d) f32 -> (d,) f32
        raise NotImplementedError

    def leaf(self, l: jax.Array) -> jax.Array:
        out = self._reduce(_as_mat(l))
        return out.reshape(l.shape[1:]).astype(l.dtype)

    def tree(self, stacked: Tree) -> Tree:
        return jax.tree.map(self.leaf, stacked)


class GeometryRule(Aggregator):
    """Rules driven by global pairwise geometry: the (m, m) statistics are
    computed once from summed per-leaf contributions, turned into per-worker
    weights, and applied per leaf by the combine primitive."""

    def _weights(self, d2: jax.Array) -> jax.Array:  # (m, m) -> (m,)|(m, m)
        raise NotImplementedError

    def tree(self, stacked: Tree) -> Tree:
        d2 = tree_pairwise_sqdist(stacked, backend=self.backend)
        return tree_weighted_combine(stacked, self._weights(d2),
                                     backend=self.backend)


# ============================================================ registry

_REGISTRY: Dict[str, Callable[..., Aggregator]] = {}


def register(name: str, factory: Callable[..., Aggregator]) -> None:
    _REGISTRY[name] = factory


def registered_rules():
    """Names registered by ``repro.core.aggregators`` (composites like
    ``nnm+<base>`` are resolved dynamically and not listed)."""
    import repro.core.aggregators  # noqa: F401  (registers the rules)
    return tuple(sorted(_REGISTRY))


def get_aggregator(name: str, delta: float = 0.25, tau: Optional[float] = None,
                   backend: str = "auto", **kwargs) -> Aggregator:
    """One registry for both training modes: Mode A consumes ``.tree()``,
    Mode B consumes ``.leaf()`` (coordinate-wise rules only). Extra rule
    hyperparameters (Krum's ``multi``, GeoMed's ``iters``/``eps``) pass
    through ``kwargs`` to the rule factory; unknown ones raise.

    Instances are memoized per (name, delta, tau, backend, kwargs): rules are
    stateless after construction, and the compiled drivers resolve the rule
    inside every traced ``lax.switch`` branch of every vmapped sweep lane
    (DESIGN.md §5, §7) — caching keeps that a dict hit instead of a
    re-registration import + object build per trace site."""
    return _cached_rule(name.lower(), delta, tau, backend,
                        tuple(sorted(kwargs.items())))


@functools.lru_cache(maxsize=None)
def _cached_rule(name: str, delta: float, tau: Optional[float],
                 backend: str, extra: tuple) -> Aggregator:
    import repro.core.aggregators as _rules  # registers on first import
    kw = dict(extra)
    if name.startswith("nnm+"):
        return _rules.NNM(get_aggregator(name[4:], delta, tau, backend, **kw),
                          delta, backend=backend)
    if name not in _REGISTRY:
        raise ValueError(f"unknown aggregator {name!r}; known: "
                         f"{registered_rules()} and nnm+<base>")
    return _REGISTRY[name](delta=delta, tau=tau, backend=backend, **kw)


def count_ceil(v: float) -> int:
    """⌈v⌉ for host-side δ·m counts, nudged exactly like ``traced_count`` so
    the class rules and the traced theta path derive identical counts. The
    nudge also corrects f64 artifacts: 0.28·25 is exactly 7, but f64 rounds
    the product to 7.000000000000001 — a bare math.ceil returns 8 there,
    diverging from both exact arithmetic and the f32 lane path."""
    # jaxlint: disable=JXL003 -- this IS the sanctioned nudged helper JXL003 points at
    return math.ceil(v - 1e-5)


def count_floor(v: float) -> int:
    """⌊v⌋ for host-side δ·m counts — the floor twin of ``count_ceil``,
    with the same 1e-5 nudge in the opposite direction: 0.3·10 is exactly 3,
    but f64 rounds the product to 2.9999999999999996, so a bare ``int()``
    truncation returns 2 (the ``Bernoulli`` cap bug this helper fixed)."""
    # jaxlint: disable=JXL003 -- this IS the sanctioned nudged helper JXL003 points at
    return math.floor(v + 1e-5)


def trim_count(delta: float, m: int) -> int:
    """⌈δm⌉ clipped to keep at least one row after two-sided trimming."""
    return min(count_ceil(delta * m), (m - 1) // 2)


# ==================================================== uniform theta dispatch
#
# The lane-batched scenario sweep (``core/robust_train.py``) runs cells with
# *different* aggregation rules as lanes of one vmapped scan, so the rule
# choice and its hyperparameters must be data, not Python constants — the
# same treatment ``core/attacks.py`` gives attacks. Every rule is exposed
# under the uniform signature ``(stacked, n, theta) -> agg_tree``: slot i of
# ``theta`` holds the i-th hyperparameter of that rule per ``AGG_PARAMS``
# (``n`` is the static mini-batch size, which MFM's auto-tau scales with),
# and ``agg_switch(names)`` builds the ``lax.switch`` applier over the
# compact branch set actually present in the sweep (DESIGN.md §4, §7).

AGG_PARAMS: Dict[str, Tuple[Tuple[str, Any], ...]] = {
    "mean": (),
    "cwmed": (),
    "cwtm": (("delta", 0.25),),
    "krum": (("delta", 0.25), ("multi", 1)),
    "geomed": (("iters", 8), ("eps", 1e-8)),
    "mfm": (("tau", None),),  # None -> NaN sentinel: auto tau from (mlmc, n)
}

# ``nnm+<base>`` composites prepend NNM's delta and share the slot with the
# base rule's delta (exactly like ``get_aggregator``, which passes one delta
# to both); the widest spec is nnm+geomed's (delta, iters, eps).
N_AGG_PARAMS = 1 + max(
    len([p for p in spec if p[0] != "delta"]) for spec in AGG_PARAMS.values())

# (rule, param) pairs where None is encoded as NaN in theta and resolved in
# the uniform form. Plain mfm only: the per-cell driver (`_aggregate`) has
# an auto-tau path for cfg.aggregator == "mfm" alone, and the lane path
# must not accept a spec whose per-cell reference run would crash —
# nnm+mfm therefore needs an explicit tau on both paths.
AGG_NAN_SENTINELS = {("mfm", "tau")}

# static unroll bound of the uniform GeoMed form: a traced ``iters`` cannot
# change the trace, so the theta path runs this many gated Weiszfeld steps
GEOMED_MAX_ITERS = 8


def agg_param_spec(name: str) -> Tuple[Tuple[str, Any], ...]:
    """(name, default) slots of ``name``'s theta vector, composites included."""
    name = name.lower()
    if name.startswith("nnm+"):
        base = agg_param_spec(name[4:])
        return (("delta", 0.25),) + tuple(p for p in base if p[0] != "delta")
    if name not in AGG_PARAMS:
        raise ValueError(f"unknown aggregator {name!r}; known: "
                         f"{tuple(sorted(AGG_PARAMS))} and nnm+<base>")
    return AGG_PARAMS[name]


def agg_param_names(name: str) -> Tuple[str, ...]:
    return tuple(p for p, _ in agg_param_spec(name))


def agg_theta(name: str,
              kwargs: Optional[Mapping[str, Any]] = None) -> np.ndarray:
    """(N_AGG_PARAMS,) float32 hyperparameter vector for ``name`` — the
    per-lane row of the sweep's (C, N_AGG_PARAMS) parameter matrix. Unset
    parameters take their ``agg_param_spec`` defaults; unknown ones raise, as
    does ``None`` for a parameter without NaN-sentinel support, or an
    ``iters`` beyond the static unroll bound ``GEOMED_MAX_ITERS``. One
    exception: ``delta`` is accepted (and discarded) even for rules without
    a delta slot, because ``get_aggregator`` takes a universal ``delta``
    parameter that such rules ignore — the lane path must not reject a spec
    the per-cell path runs."""
    kw = dict(kwargs or {})
    if "delta" not in agg_param_names(name):
        kw.pop("delta", None)
    theta = np.zeros(N_AGG_PARAMS, np.float32)
    for i, (pname, default) in enumerate(agg_param_spec(name)):
        val = kw.pop(pname, default)
        if val is None and (name, pname) not in AGG_NAN_SENTINELS:
            raise TypeError(
                f"{name!r} aggregator parameter {pname!r} does not accept None")
        if pname == "iters" and val is not None and val > GEOMED_MAX_ITERS:
            raise ValueError(
                f"{name!r}: iters={val} exceeds the uniform form's static "
                f"unroll bound GEOMED_MAX_ITERS={GEOMED_MAX_ITERS}; use the "
                f"class rule (get_aggregator) for longer Weiszfeld runs")
        theta[i] = np.nan if val is None else float(val)
    if kw:
        raise TypeError(f"unknown {name!r} aggregator parameter(s): {sorted(kw)}")
    return theta


def traced_count(v) -> jax.Array:
    """⌈v⌉ as int32 for a (possibly traced) f32 count like δ·m — the traced
    twin of ``count_ceil``. The shared 1e-5 nudge (well over half an f32 ulp
    of any realistic δ·m < 32) keeps both paths agreeing on exact-integer
    products, where bare f64/f32 ceils would round up on representation
    noise. Products within 1e-5 of an integer boundary are the caller's
    precision problem either way."""
    return jnp.ceil(jnp.asarray(v, jnp.float32) - 1e-5).astype(jnp.int32)


def traced_trim_count(delta, m: int) -> jax.Array:
    """``trim_count`` for a traced delta (same clipping, in-graph)."""
    return jnp.clip(traced_count(delta * m), 0, (m - 1) // 2)


_UNIFORM: Dict[str, Callable] = {}


def register_uniform(name: str, builder: Callable) -> None:
    """``builder(backend, mlmc) -> fn(stacked, n, theta)``; the special key
    ``"nnm"`` registers the composite wrapper ``builder(base_name, backend,
    mlmc)``."""
    _UNIFORM[name] = builder


def uniform_aggregator(name: str, *, backend: str = "auto", mlmc=None):
    """``name`` under the uniform ``(stacked, n, theta)`` signature — the
    ``lax.switch`` branch form, reading hyperparameters from theta slots.

    ``mlmc`` (an ``MLMCConfig``) supplies MFM's auto threshold
    ``mlmc.mfm_tau(n)`` when the tau slot carries the NaN sentinel; without
    it a NaN tau propagates NaN weights, so direct callers should pass an
    explicit tau. Matches ``get_aggregator(name, ...)`` bitwise on the ref
    backend for equal hyperparameters (the class rules run the identical
    masked cores — ``tests/test_agg_engine.py``)."""
    import repro.core.aggregators  # noqa: F401  (registers the forms)
    name = name.lower()
    agg_param_spec(name)  # validates the name
    if name.startswith("nnm+"):
        return _UNIFORM["nnm"](name[4:], backend, mlmc)
    return _UNIFORM[name](backend, mlmc)


def _per_level(fn, stacked, n, theta):
    """Run a uniform form at one batch size — or, when ``n`` is a tuple, at
    each of several (the leaves of ``stacked`` then carry a leading level
    axis, and so does the result). The per-level applications are the exact
    scalar-``n`` calls, just unrolled inside one dispatch."""
    if not isinstance(n, tuple):
        return fn(stacked, n, theta)
    outs = [fn(jax.tree.map(lambda l, i=i: l[i], stacked), ni, theta)
            for i, ni in enumerate(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)


def agg_switch(names: Sequence[str], *, backend: str = "auto",
               mlmc=None) -> Callable:
    """``apply(idx, stacked, n, theta)`` dispatching ``lax.switch`` over the
    uniform forms of ``names`` (``idx`` indexes into ``names``; ``n`` is
    static). Under ``vmap`` with a lane-mapped idx this lowers to
    execute-all-branches-and-select — acceptable, since aggregation is
    O(m²·d) next to the per-worker gradient work. A single name skips the
    switch entirely.

    ``n`` may also be a *tuple* of batch sizes with a matching leading level
    axis on ``stacked``: all levels then run inside ONE switch dispatch.
    That is how the MLMC scan body aggregates its three levels — the
    execute-all-branches select is paid once per round instead of once per
    level, which is most of the lane-batched sweep's overhead at small m·d
    (DESIGN.md §7)."""
    branches = tuple(uniform_aggregator(nm, backend=backend, mlmc=mlmc)
                     for nm in names)
    if len(branches) == 1:
        only = branches[0]
        return lambda idx, stacked, n, theta: _per_level(only, stacked, n,
                                                         theta)

    def apply(idx, stacked, n, theta):
        return jax.lax.switch(
            idx,
            [lambda op, b=b: _per_level(b, op[0], n, op[1]) for b in branches],
            (stacked, theta))

    return apply
