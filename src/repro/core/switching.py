"""Identity-switching strategies (Section 6).

Host-side, seeded, reproducible. Each strategy yields a boolean mask (m,)
per round: True = Byzantine. ``within_round(t, k)`` supports the dynamic-round
model of Section 4 where identities may flip between the k-th gradient
computations of one round (data poisoning); the default strategies only switch
*between* rounds (τ_d = ∅ w.r.t. within-round changes).
"""
from __future__ import annotations

import numpy as np

from repro.core.agg_engine import count_floor


class Switcher:
    def __init__(self, m: int, seed: int = 0):
        self.m = m
        self.seed = seed
        self.rng = np.random.default_rng(seed)

    def mask(self, t: int) -> np.ndarray:
        raise NotImplementedError

    def within_round(self, t: int, k: int) -> np.ndarray:
        """Mask at the k-th gradient computation of round t (default: static)."""
        return self.mask(t)

    def mask_schedule(self, T: int, n_max: int = 1) -> np.ndarray:
        """Full identity schedule as a (T, n_max, m) bool tensor with entry
        ``[t, k] == within_round(t, k)`` — the device-side input of the
        compiled ``lax.scan`` driver (DESIGN.md §5). ``within_round`` is
        assumed to be a pure function of (t, k); strategies whose masks carry
        hidden per-call state must keep it in ``mask`` (as ``Bernoulli``
        does, idempotently), or the schedule cannot be precomputed.

        Strategies that only switch *between* rounds supply a vectorized
        (T, m) fast path via ``_mask_schedule_rounds``; it is broadcast over
        the within-round axis. The fast path is bypassed when it cannot be
        trusted for this instance: when ``within_round`` is overridden, or
        when ``mask`` is overridden below the class that provided the fast
        path (the parent's vectorization knows nothing of the new masks)."""
        if T <= 0:
            return np.zeros((0, n_max, self.m), bool)
        cls = type(self)

        def defining(name):
            for c in cls.__mro__:
                if name in c.__dict__:
                    return c
            return Switcher

        if (cls.within_round is Switcher.within_round
                and issubclass(defining("_mask_schedule_rounds"),
                               defining("mask"))):
            rounds = self._mask_schedule_rounds(T)
            if rounds is not None:
                return np.broadcast_to(rounds[:, None, :],
                                       (T, n_max, self.m)).copy()
        out = np.empty((T, n_max, self.m), bool)
        for t in range(T):
            for k in range(n_max):
                out[t, k] = self.within_round(t, k)
        return out

    def _mask_schedule_rounds(self, T: int):
        """Vectorized (T, m) between-round schedule, or None for the generic
        per-(t, k) loop."""
        return None

    def switch_rounds(self, T: int) -> int:
        """|rounds with a different mask than the previous round| (≈ |τ_d|
        in the between-round sense used by the experiments)."""
        n, prev = 0, None
        for t in range(T):
            cur = tuple(self.mask(t))
            if prev is not None and cur != prev:
                n += 1
            prev = cur
        return n


class Static(Switcher):
    """Fixed Byzantine set (the classical setting)."""

    def __init__(self, m: int, n_byz: int, seed: int = 0):
        super().__init__(m, seed)
        self._mask = np.zeros(m, bool)
        idx = self.rng.choice(m, n_byz, replace=False)
        self._mask[idx] = True

    def mask(self, t):
        return self._mask

    def _mask_schedule_rounds(self, T):
        return np.broadcast_to(self._mask, (T, self.m))


class Periodic(Switcher):
    """Periodic(K): resample the δm Byzantine workers every K rounds."""

    def __init__(self, m: int, n_byz: int, K: int, seed: int = 0):
        super().__init__(m, seed)
        self.n_byz = n_byz
        self.K = K
        self._cache = {}

    def mask(self, t):
        e = t // self.K
        if e not in self._cache:
            rng = np.random.default_rng(self.seed * 1_000_003 + e)
            mask = np.zeros(self.m, bool)
            mask[rng.choice(self.m, self.n_byz, replace=False)] = True
            self._cache[e] = mask
        return self._cache[e]

    def _mask_schedule_rounds(self, T):
        epochs = np.arange(T) // self.K
        per_epoch = np.stack([self.mask(e * self.K) for e in range(epochs[-1] + 1)])
        return per_epoch[epochs]


class Bernoulli(Switcher):
    """Bernoulli(p, D, δmax): each worker independently turns Byzantine with
    prob p per round, for a fixed duration of D rounds, capped at δmax·m
    simultaneous Byzantine workers."""

    def __init__(self, m: int, p: float, D: int, delta_max: float, seed: int = 0):
        super().__init__(m, seed)
        self.p = p
        self.D = D
        # nudged floor: a bare int() truncation of the f64 product caps one
        # worker short at exact boundaries (int(0.3 * 10) == 2, exact is 3)
        self.cap = count_floor(delta_max * m)
        self._until = np.zeros(m, np.int64)  # byz until round (exclusive)
        self._computed_to = 0

    def _advance(self, t):
        while self._computed_to <= t:
            s = self._computed_to
            active = (self._until > s).sum()
            draws = self.rng.random(self.m) < self.p
            for i in np.nonzero(draws)[0]:
                if self._until[i] <= s and active < self.cap:
                    self._until[i] = s + self.D
                    active += 1
            self._computed_to += 1

    def mask(self, t):
        self._advance(t)
        return self._until > t

    def _mask_schedule_rounds(self, T):
        # inherently sequential (each round's draws depend on who is already
        # infected), but one row per round — the n_max axis is broadcast
        return np.stack([self.mask(t) for t in range(T)])


class MomentumTailored(Switcher):
    """Appendix E: rotate the single Byzantine worker among 3 groups, once per
    1/(3α) rounds — defeats worker-momentum with only O(√T) switches."""

    def __init__(self, m: int, alpha: float, seed: int = 0):
        super().__init__(m, seed)
        self.alpha = alpha
        self.period = max(int(round(1.0 / alpha)), 3)
        self.third = max(self.period // 3, 1)

    def mask(self, t):
        g = (t % self.period) // self.third % 3
        mask = np.zeros(self.m, bool)
        # group g of 3 equal groups is Byzantine
        lo = g * self.m // 3
        hi = (g + 1) * self.m // 3
        mask[lo:hi] = True
        return mask

    def _mask_schedule_rounds(self, T):
        g = (np.arange(T) % self.period) // self.third % 3  # (T,) group index
        ranks = np.arange(self.m)
        lo, hi = g * self.m // 3, (g + 1) * self.m // 3
        return (ranks[None, :] >= lo[:, None]) & (ranks[None, :] < hi[:, None])


def get_switcher(name: str, m: int, seed: int = 0, **kw) -> Switcher:
    return {
        "static": Static,
        "periodic": Periodic,
        "bernoulli": Bernoulli,
        "momentum_tailored": MomentumTailored,
    }[name](m, seed=seed, **kw)
