"""Scenario-matrix runner: attack × switcher × aggregator sweeps through the
compiled ``lax.scan`` driver (DESIGN.md §5, §7).

Large-`T` grids are the workload the paper's Section 6 figures need (and what
the ROADMAP's many-scenario coverage goal means): every cell is one full
DynaBRO (or worker-momentum baseline) run, so the per-round dispatch cost of
the Python-loop drivers multiplies across the grid. ``run_matrix`` drives
every cell through ``run_dynabro_scan`` and returns a tidy list-of-dicts
results table; ``driver="vmap"`` batches the ENTIRE grid — attack, attack
kwargs, switcher, aggregator and aggregator kwargs all vary per lane — into
ONE vmapped compiled call (``run_dynabro_scan_sweep`` with per-lane attack
and aggregator dispatch — no re-trace, no per-cell or per-group dispatch);
``format_table`` pivots the rows for terminal display, disambiguating cells
that differ only in kwargs.

Aggregator hyperparameters are a scenario axis of their own: because rule
parameters are *traced* theta data in the engine (DESIGN.md §4), grids
varying only ``delta`` / ``tau`` / ``multi`` / ``iters`` — e.g. CWTM at
δ ∈ {0.1, 0.25, 0.4} — are free lanes of the same dispatch, written
``("cwtm", {"delta": 0.4})`` exactly like attack kwarg variants.

Used by ``examples/attack_gallery.py`` and ``benchmarks/bench_scan_driver.py``.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, List, Mapping, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.agg_engine import agg_param_names
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import (
    DynaBROConfig, run_dynabro, run_dynabro_scan,
)
from repro.core.switching import get_switcher
from repro.optim.optimizers import Optimizer, sgd

# grid entries: a bare name or (name, kwargs)
Spec = Union[str, Tuple[str, Mapping[str, Any]]]


def _norm(spec: Spec) -> Tuple[str, Dict[str, Any]]:
    if isinstance(spec, str):
        return spec, {}
    name, kw = spec
    return name, dict(kw)


def _fmt_kw(kw: Tuple[Tuple[str, Any], ...]) -> str:
    return ",".join(f"{k}={v}" for k, v in kw)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One cell of the sweep grid."""
    attack: str
    switcher: str
    aggregator: str
    attack_kwargs: Tuple[Tuple[str, Any], ...] = ()
    switcher_kwargs: Tuple[Tuple[str, Any], ...] = ()
    aggregator_kwargs: Tuple[Tuple[str, Any], ...] = ()

    @property
    def attack_label(self) -> str:
        """Attack name qualified with its kwargs — ``ipm(eps=0.3)`` — so
        grids that vary only a parameter stay distinguishable."""
        kw = _fmt_kw(self.attack_kwargs)
        return f"{self.attack}({kw})" if kw else self.attack

    @property
    def switcher_label(self) -> str:
        kw = _fmt_kw(self.switcher_kwargs)
        return f"{self.switcher}({kw})" if kw else self.switcher

    @property
    def aggregator_label(self) -> str:
        """Rule name qualified with its hyperparameters — ``cwtm(delta=0.4)``
        — so delta/tau-only grids keep distinct pivot lines."""
        kw = _fmt_kw(self.aggregator_kwargs)
        return f"{self.aggregator}({kw})" if kw else self.aggregator

    @property
    def name(self) -> str:
        return (f"{self.attack_label}|{self.switcher_label}|"
                f"{self.aggregator_label}")


def scenario_grid(attacks: Sequence[Spec], switchers: Sequence[Spec],
                  aggregators: Sequence[Spec]) -> List[Scenario]:
    """Cartesian product of the three grid axes; every axis takes bare names
    or ``(name, kwargs)`` — aggregator kwargs are rule hyperparameters
    (``delta`` / ``tau`` / ``multi`` / ``iters``, see ``agg_engine``)."""
    out = []
    for a in attacks:
        an, akw = _norm(a)
        for s in switchers:
            sn, skw = _norm(s)
            for g in aggregators:
                gn, gkw = _norm(g)
                out.append(Scenario(an, sn, gn, tuple(sorted(akw.items())),
                                    tuple(sorted(skw.items())),
                                    tuple(sorted(gkw.items()))))
    return out


@dataclasses.dataclass
class Task:
    """A Mode-A testbed: initial params, per-unit grad fn, batch sampler
    factory (m -> sample_batches), and a scalar objective for reporting."""
    params0: Any
    grad_fn: Callable[[Any, Any], Any]
    make_sampler: Callable[[int], Callable[[int, int], Any]]
    objective: Callable[[Any], float]


def make_quadratic_task(sigma: float = 0.5, seed: int = 0) -> Task:
    """The paper's 2D quadratic testbed (Appendix E): f(x) = ½ xᵀAx, exact
    optimum 0, per-unit gradients perturbed by N(0, σ²). Shared by the
    examples, the scan-driver benchmark and the parity tests."""
    A = jnp.array([[2.0, 1.0], [1.0, 2.0]])
    params0 = {"x": jnp.array([3.0, -2.0])}

    def grad_fn(params, unit_key):
        return {"x": A @ params["x"] + sigma * jax.random.normal(unit_key, (2,))}

    def make_sampler(m, sampler_seed=None):
        s = seed if sampler_seed is None else sampler_seed
        def sample(t, n):
            keys = jax.random.split(
                jax.random.fold_in(jax.random.PRNGKey(s), t), m * n)
            return keys.reshape(m, n, *keys.shape[1:])
        return sample

    def objective(p):
        return float(0.5 * p["x"] @ A @ p["x"])

    return Task(params0, grad_fn, make_sampler, objective)


def _cell_cfg(sc: Scenario, m: int, T: int, V: float, kappa: float,
              j_cap: int, use_mlmc: bool, delta: float) -> DynaBROConfig:
    """One cfg builder for the per-cell and vmapped paths — they must agree
    for ``driver="vmap"`` to be a drop-in. A ``delta`` in the scenario's
    aggregator kwargs overrides the grid-wide default."""
    akw = dict(sc.aggregator_kwargs)
    return DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=m, V=V,
                        option=2 if sc.aggregator == "mfm" else 1,
                        kappa=kappa, j_cap=j_cap),
        aggregator=sc.aggregator, delta=akw.get("delta", delta),
        attack=sc.attack, attack_kwargs=dict(sc.attack_kwargs) or None,
        use_mlmc=use_mlmc, aggregator_kwargs=akw or None)


def _agg_spec(sc: Scenario, delta: float):
    """The per-lane aggregator spec of the vmapped sweep: the scenario's
    kwargs, with the grid-wide ``delta`` filled in for rules that take one
    (so the lane theta matches ``_cell_cfg``'s per-cell delta)."""
    kw = dict(sc.aggregator_kwargs)
    if "delta" not in kw and "delta" in agg_param_names(sc.aggregator):
        kw["delta"] = delta
    return (sc.aggregator, kw)


def _row(task: Task, sc: Scenario, params, logs, *, driver: str, m: int,
         T: int, wall: float) -> Dict[str, Any]:
    return {
        "attack": sc.attack, "attack_label": sc.attack_label,
        "switcher": sc.switcher, "switcher_label": sc.switcher_label,
        "aggregator": sc.aggregator,
        "aggregator_label": sc.aggregator_label,
        "driver": driver, "m": m, "T": T,
        "final": task.objective(params),
        "failsafe_trips": sum(1 for l in logs if l.level >= 1 and not l.failsafe_ok),
        "mean_level": sum(l.level for l in logs) / max(len(logs), 1),
        "cost": sum(l.cost for l in logs),
        "wall_s": wall,
    }


def _stat_row(task: Task, sc: Scenario, cell, *, m: int, T: int,
              wall: float) -> Dict[str, Any]:
    """One results row for a cell's replicate lanes (``cell`` is the
    ``[(params, logs), ...]`` list of one cell): the single-run row shape
    plus the replicate statistics columns ``final_mean`` / ``final_std`` /
    ``final_stderr`` / ``n_seeds`` (DESIGN.md §12). With one replicate the
    statistics degenerate (std = stderr = 0.0, ``final`` untouched); with
    several, ``final`` becomes the replicate mean — honest sample std
    (ddof=1), not a typographic ±0 — and the log-derived columns
    (``failsafe_trips`` / ``mean_level`` / ``cost``) average over lanes."""
    per = [_row(task, sc, p, logs, driver="vmap", m=m, T=T, wall=wall)
           for p, logs in cell]
    r = dict(per[0])
    n = len(per)
    finals = [p["final"] for p in per]
    mean = sum(finals) / n
    r["n_seeds"] = n
    r["final_mean"] = mean
    if n > 1:
        var = sum((f - mean) ** 2 for f in finals) / (n - 1)
        r["final_std"] = var ** 0.5
        r["final_stderr"] = (var / n) ** 0.5
        r["final"] = mean
        for k in ("failsafe_trips", "mean_level", "cost"):
            r[k] = sum(p[k] for p in per) / n
    else:
        r["final_std"] = 0.0
        r["final_stderr"] = 0.0
    return r


def run_scenario(
    task: Task,
    sc: Scenario,
    *,
    m: int,
    T: int,
    V: float,
    make_opt: Callable[[], Optimizer] = lambda: sgd(2e-2),
    delta: float = 0.25,
    kappa: float = 1.0,
    j_cap: int = 7,
    use_mlmc: bool = True,
    seed: int = 0,
    driver: str = "scan",
    chunk: int = 0,
    mesh=None,
) -> Dict[str, Any]:
    """Run one grid cell end to end; returns a tidy results row. ``mesh``
    (with ``driver="scan"``) runs the cell through the sharded compiled
    driver (DESIGN.md §7); ``driver="vmap"`` routes through the
    single-lane vmapped sweep."""
    if mesh is not None and driver != "scan":
        raise ValueError(
            f"mesh= requires driver='scan' (the sharded compiled driver); "
            f"got driver={driver!r}")
    if driver == "vmap":
        return run_matrix_vmapped(
            task, [sc], m=m, T=T, V=V, make_opt=make_opt, delta=delta,
            kappa=kappa, j_cap=j_cap, use_mlmc=use_mlmc, seed=seed,
            chunk=chunk)[0]
    if driver not in ("scan", "legacy"):
        raise ValueError(
            f"unknown driver {driver!r}; expected 'scan', 'legacy' or 'vmap'")
    cfg = _cell_cfg(sc, m, T, V, kappa, j_cap, use_mlmc, delta)
    switcher = get_switcher(sc.switcher, m, seed=seed,
                            **dict(sc.switcher_kwargs))
    run = run_dynabro_scan if driver == "scan" else run_dynabro
    kw = {"chunk": chunk, "mesh": mesh} if driver == "scan" else {}
    t0 = time.perf_counter()
    params, logs, _ = run(task.grad_fn, task.params0, make_opt(), cfg,
                          switcher, task.make_sampler(m), T, seed=seed, **kw)
    jax.block_until_ready(jax.tree.leaves(params))
    wall = time.perf_counter() - t0
    return _row(task, sc, params, logs, driver=driver, m=m, T=T, wall=wall)


def run_matrix(
    task: Task,
    scenarios: Sequence[Scenario],
    *,
    m: int,
    T: int,
    V: float,
    **kw,
) -> List[Dict[str, Any]]:
    """Sweep every scenario through the compiled driver -> results table.

    ``driver="vmap"`` routes through ``run_matrix_vmapped`` (the whole grid
    as lanes of ONE vmapped compiled dispatch; combine with the per-run
    worker ``mesh=`` and it raises — lane-axis sharding goes through
    ``lane_mesh=`` instead) and is the only driver that takes the replicate
    statistics axis (``seeds=`` / ``replicates=``, plus ``lane_chunk=`` /
    ``lane_mesh=`` scaling knobs); ``"scan"`` / ``"legacy"`` run one driver
    call per cell."""
    if kw.get("driver") == "vmap":
        if kw.get("mesh") is not None:
            raise ValueError(
                "driver='vmap' sweeps run unsharded per lane; drop mesh= "
                "(lane_mesh= shards the lane axis) or use driver='scan' "
                "for the sharded per-cell driver")
        kw = {k: v for k, v in kw.items() if k not in ("driver", "mesh")}
        return run_matrix_vmapped(task, scenarios, m=m, T=T, V=V, **kw)
    for rep_kw in ("seeds", "replicates", "lane_chunk", "lane_mesh"):
        if kw.get(rep_kw):
            raise ValueError(
                f"{rep_kw}= is a replicate-lane option of the vmapped sweep; "
                f"pass driver='vmap' (per-cell drivers run one seed per "
                f"call)")
    return [run_scenario(task, sc, m=m, T=T, V=V, **kw) for sc in scenarios]


def run_matrix_vmapped(
    task: Task,
    scenarios: Sequence[Scenario],
    *,
    m: int,
    T: int,
    V: float,
    make_opt: Callable[[], Optimizer] = lambda: sgd(2e-2),
    delta: float = 0.25,
    kappa: float = 1.0,
    j_cap: int = 7,
    use_mlmc: bool = True,
    seed: int = 0,
    chunk: int = 0,
    seeds=None,
    replicates=None,
    lane_chunk: int = 0,
    lane_mesh=None,
) -> List[Dict[str, Any]]:
    """Sweep a grid with every cell a lane of ONE vmapped dispatch
    (DESIGN.md §7).

    No grid axis shapes the traced computation any more: attacks AND
    aggregation rules dispatch per lane through traced-theta ``lax.switch``
    layers, so the whole attack × switcher × aggregator grid — aggregator
    hyperparameter variants included — runs as lanes of a single
    ``run_dynabro_scan_sweep`` call: one compile, one dispatch, regardless of
    grid shape, with equivalent numerics (``tests/test_scenarios.py`` locks
    rows to the per-cell loop — exact round logs, floats within the parity
    suite's 1e-6). Rows come back in input order; duplicate scenarios are
    just duplicate lanes. ``wall_s`` is the grid wall clock amortized over
    its lanes. One sampler is shared by every lane (lanes share batch draws
    by construction), so ``task.make_sampler`` must return *pure* samplers —
    samplers with hidden per-call state need the per-cell drivers
    (``driver="scan"`` with ``vectorize_batches=False``).

    ``seeds=`` / ``replicates=`` add the replicate statistics axis
    (DESIGN.md §12): every cell runs one extra lane per replicate seed —
    switcher mask schedule, attack key stream AND data sampler each fold the
    replicate seed (the sampler through ``task.make_sampler(m,
    sampler_seed=...)``, which the task must accept), so replicate lanes are
    genuinely distinct draws, paired across cells. Rows then carry
    ``final_mean`` / ``final_std`` / ``final_stderr`` (``final`` = the mean)
    with ``n_seeds`` = the replicate count; without the axis the columns
    degenerate to std = stderr = 0.0, ``n_seeds`` = 1 and the row values are
    bitwise those of the un-replicated sweep. ``lane_chunk`` streams huge
    grids through fixed-size cell chunks; ``lane_mesh`` (a
    ``launch.mesh.make_lane_mesh`` mesh) shards the cell axis across
    devices."""
    scs = list(scenarios)
    if not scs:
        return []
    # the shared cfg's aggregator/option fields are inert in lane mode (rule
    # and fail-safe coefficient are per-lane data), but build it through
    # _cell_cfg anyway so the two paths cannot drift
    cfg = _cell_cfg(scs[0], m, T, V, kappa, j_cap, use_mlmc, delta)
    from repro.api.session import Session, _task_sampler_factory
    from repro.api.specs import SweepSpec
    spec = SweepSpec(
        switchers=tuple((sc.switcher, dict(sc.switcher_kwargs))
                        for sc in scs),
        attacks=tuple((sc.attack, dict(sc.attack_kwargs)) for sc in scs),
        aggregators=tuple(_agg_spec(sc, delta) for sc in scs),
        seeds=None if seeds is None else tuple(int(s) for s in seeds),
        replicates=None if replicates is None else int(replicates))
    factory = None
    if spec.n_replicates > 1 or spec.seeds is not None:
        factory = _task_sampler_factory(task, m)
        if factory is None:
            raise ValueError(
                "seeds=/replicates= need per-replicate data streams, but "
                "task.make_sampler does not accept sampler_seed=; add the "
                "kwarg (see make_quadratic_task) or drop the replicate axis")
    sess = Session(cfg, grad_fn=task.grad_fn, params0=task.params0,
                   opt=make_opt(), m=m, sample_batches=task.make_sampler(m),
                   seed=seed, sampler_factory=factory)
    replicated = spec.n_replicates > 1
    t0 = time.perf_counter()
    outs = sess.sweep(spec, T, chunk=chunk, lane_chunk=lane_chunk,
                      lane_mesh=lane_mesh)
    cells = outs if replicated else [[cell] for cell in outs]
    jax.block_until_ready([l for cell in cells for p, _ in cell
                           for l in jax.tree.leaves(p)])
    wall = (time.perf_counter() - t0) / len(scs)
    return [_stat_row(task, sc, cell, m=m, T=T, wall=wall)
            for sc, cell in zip(scs, cells)]


def format_table(rows: Sequence[Dict[str, Any]], value: str = "final",
                 row_key: str = "aggregator", col_key: str = "attack") -> str:
    """Pivot a results table for terminal display (one line per row_key).

    Keys use the kwarg-qualified ``<key>_label`` row field when present (so
    cells that differ only in ``eps``/``z``/``K`` get their own column/line
    instead of silently collapsing). If several rows still land on one
    (row, col) cell with *different* values — a residual collision the labels
    cannot split, e.g. pivoting away a varying axis — a RuntimeWarning names
    the cell and the first value is shown; duplicate rows with equal values
    (duplicate scenarios) stay silent.

    Rows carrying the replicate statistics columns (``n_seeds > 1`` with a
    ``<value>_mean`` / ``<value>_std`` pair, DESIGN.md §12) render as
    ``mean±std``; single-seed rows render the bare value — never a
    typographic ``±0.0000``."""
    def label(r, k):
        return str(r.get(f"{k}_label", r[k]))

    def differs(a, b):
        # NaN compares unequal to itself; duplicate lanes of a diverged
        # scenario (both NaN) are still duplicates, not a collision
        return a != b and not (a != a and b != b)

    def cell_str(r):
        if r.get("n_seeds", 1) > 1 and f"{value}_mean" in r:
            return f"{r[f'{value}_mean']:.4f}±{r[f'{value}_std']:.4f}"
        return f"{r[value]:.4f}"

    cols = list(dict.fromkeys(label(r, col_key) for r in rows))
    rks = list(dict.fromkeys(label(r, row_key) for r in rows))
    cells = {}
    for rk in rks:
        for c in cols:
            sel = [r for r in rows
                   if label(r, row_key) == rk and label(r, col_key) == c]
            if not sel:
                continue
            if len(sel) > 1 and any(differs(v[value], sel[0][value])
                                    for v in sel[1:]):
                warnings.warn(
                    f"format_table: {len(sel)} rows collide on cell "
                    f"({rk!r}, {c!r}) with differing {value!r} values; "
                    f"showing the first — pivot on a distinguishing key",
                    RuntimeWarning, stacklevel=2)
            cells[(rk, c)] = cell_str(sel[0])
    cw = max([12] + [len(c) + 2 for c in cols]
             + [len(s) + 2 for s in cells.values()])
    rw = max([12] + [len(rk) + 1 for rk in rks])
    lines = [" " * rw + "".join(f"{c:>{cw}s}" for c in cols)]
    for rk in rks:
        lines.append(f"{rk:{rw}s}" + "".join(
            f"{cells.get((rk, c), '—'):>{cw}s}" for c in cols))
    return "\n".join(lines)
