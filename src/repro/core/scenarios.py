"""Scenario-matrix runner: attack × switcher × aggregator sweeps through the
compiled ``lax.scan`` driver (DESIGN.md §5, §7).

Large-`T` grids are the workload the paper's Section 6 figures need (and what
the ROADMAP's many-scenario coverage goal means): every cell is one full
DynaBRO (or worker-momentum baseline) run, so the per-round dispatch cost of
the Python-loop drivers multiplies across the grid. ``run_matrix`` drives
every cell through ``run_dynabro_scan`` and returns a tidy list-of-dicts
results table; ``driver="vmap"`` instead batches cells that differ only in
their switching strategy into one vmapped compiled call per group
(``run_dynabro_scan_sweep`` — no re-trace, no per-cell dispatch);
``format_table`` pivots the rows for terminal display.

Used by ``examples/attack_gallery.py`` and ``benchmarks/bench_scan_driver.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Mapping, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import (
    DynaBROConfig, run_dynabro, run_dynabro_scan, run_dynabro_scan_sweep,
)
from repro.core.switching import get_switcher
from repro.optim.optimizers import Optimizer, sgd

# grid entries: a bare name or (name, kwargs)
Spec = Union[str, Tuple[str, Mapping[str, Any]]]


def _norm(spec: Spec) -> Tuple[str, Dict[str, Any]]:
    if isinstance(spec, str):
        return spec, {}
    name, kw = spec
    return name, dict(kw)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One cell of the sweep grid."""
    attack: str
    switcher: str
    aggregator: str
    attack_kwargs: Tuple[Tuple[str, Any], ...] = ()
    switcher_kwargs: Tuple[Tuple[str, Any], ...] = ()

    @property
    def name(self) -> str:
        return f"{self.attack}|{self.switcher}|{self.aggregator}"


def scenario_grid(attacks: Sequence[Spec], switchers: Sequence[Spec],
                  aggregators: Sequence[str]) -> List[Scenario]:
    """Cartesian product of the three grid axes."""
    out = []
    for a in attacks:
        an, akw = _norm(a)
        for s in switchers:
            sn, skw = _norm(s)
            for g in aggregators:
                out.append(Scenario(an, sn, g, tuple(sorted(akw.items())),
                                    tuple(sorted(skw.items()))))
    return out


@dataclasses.dataclass
class Task:
    """A Mode-A testbed: initial params, per-unit grad fn, batch sampler
    factory (m -> sample_batches), and a scalar objective for reporting."""
    params0: Any
    grad_fn: Callable[[Any, Any], Any]
    make_sampler: Callable[[int], Callable[[int, int], Any]]
    objective: Callable[[Any], float]


def make_quadratic_task(sigma: float = 0.5, seed: int = 0) -> Task:
    """The paper's 2D quadratic testbed (Appendix E): f(x) = ½ xᵀAx, exact
    optimum 0, per-unit gradients perturbed by N(0, σ²). Shared by the
    examples, the scan-driver benchmark and the parity tests."""
    A = jnp.array([[2.0, 1.0], [1.0, 2.0]])
    params0 = {"x": jnp.array([3.0, -2.0])}

    def grad_fn(params, unit_key):
        return {"x": A @ params["x"] + sigma * jax.random.normal(unit_key, (2,))}

    def make_sampler(m):
        def sample(t, n):
            keys = jax.random.split(
                jax.random.fold_in(jax.random.PRNGKey(seed), t), m * n)
            return keys.reshape(m, n, *keys.shape[1:])
        return sample

    def objective(p):
        return float(0.5 * p["x"] @ A @ p["x"])

    return Task(params0, grad_fn, make_sampler, objective)


def _cell_cfg(sc: Scenario, m: int, T: int, V: float, kappa: float,
              j_cap: int, use_mlmc: bool, delta: float) -> DynaBROConfig:
    """One cfg builder for the per-cell and vmapped paths — they must agree
    for ``driver="vmap"`` to be a drop-in."""
    return DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=m, V=V,
                        option=2 if sc.aggregator == "mfm" else 1,
                        kappa=kappa, j_cap=j_cap),
        aggregator=sc.aggregator, delta=delta, attack=sc.attack,
        attack_kwargs=dict(sc.attack_kwargs) or None, use_mlmc=use_mlmc)


def _row(task: Task, sc: Scenario, params, logs, *, driver: str, m: int,
         T: int, wall: float) -> Dict[str, Any]:
    return {
        "attack": sc.attack, "switcher": sc.switcher,
        "aggregator": sc.aggregator, "driver": driver, "m": m, "T": T,
        "final": task.objective(params),
        "failsafe_trips": sum(1 for l in logs if l.level >= 1 and not l.failsafe_ok),
        "mean_level": sum(l.level for l in logs) / max(len(logs), 1),
        "cost": sum(l.cost for l in logs),
        "wall_s": wall,
    }


def run_scenario(
    task: Task,
    sc: Scenario,
    *,
    m: int,
    T: int,
    V: float,
    make_opt: Callable[[], Optimizer] = lambda: sgd(2e-2),
    delta: float = 0.25,
    kappa: float = 1.0,
    j_cap: int = 7,
    use_mlmc: bool = True,
    seed: int = 0,
    driver: str = "scan",
    chunk: int = 0,
    mesh=None,
) -> Dict[str, Any]:
    """Run one grid cell end to end; returns a tidy results row. ``mesh``
    (with ``driver="scan"``) runs the cell through the sharded compiled
    driver (DESIGN.md §7)."""
    if mesh is not None and driver != "scan":
        raise ValueError(
            f"mesh= requires driver='scan' (the sharded compiled driver); "
            f"got driver={driver!r}")
    cfg = _cell_cfg(sc, m, T, V, kappa, j_cap, use_mlmc, delta)
    switcher = get_switcher(sc.switcher, m, seed=seed,
                            **dict(sc.switcher_kwargs))
    run = run_dynabro_scan if driver == "scan" else run_dynabro
    kw = {"chunk": chunk, "mesh": mesh} if driver == "scan" else {}
    t0 = time.perf_counter()
    params, logs, _ = run(task.grad_fn, task.params0, make_opt(), cfg,
                          switcher, task.make_sampler(m), T, seed=seed, **kw)
    jax.block_until_ready(jax.tree.leaves(params))
    wall = time.perf_counter() - t0
    return _row(task, sc, params, logs, driver=driver, m=m, T=T, wall=wall)


def run_matrix(
    task: Task,
    scenarios: Sequence[Scenario],
    *,
    m: int,
    T: int,
    V: float,
    **kw,
) -> List[Dict[str, Any]]:
    """Sweep every scenario through the compiled driver -> results table.

    ``driver="vmap"`` routes through ``run_matrix_vmapped`` (cells batched
    into vmapped lane groups; unsharded only — combine with ``mesh=`` and it
    raises); ``"scan"`` / ``"legacy"`` run one driver call per cell."""
    if kw.get("driver") == "vmap":
        if kw.get("mesh") is not None:
            raise ValueError(
                "driver='vmap' sweeps run unsharded; drop mesh= or use "
                "driver='scan' for the sharded per-cell driver")
        kw = {k: v for k, v in kw.items() if k not in ("driver", "mesh")}
        return run_matrix_vmapped(task, scenarios, m=m, T=T, V=V, **kw)
    return [run_scenario(task, sc, m=m, T=T, V=V, **kw) for sc in scenarios]


def run_matrix_vmapped(
    task: Task,
    scenarios: Sequence[Scenario],
    *,
    m: int,
    T: int,
    V: float,
    make_opt: Callable[[], Optimizer] = lambda: sgd(2e-2),
    delta: float = 0.25,
    kappa: float = 1.0,
    j_cap: int = 7,
    use_mlmc: bool = True,
    seed: int = 0,
    chunk: int = 0,
) -> List[Dict[str, Any]]:
    """Sweep a grid with cells batched into vmapped lanes (DESIGN.md §7).

    Cells are grouped by everything that shapes the traced computation —
    (attack, attack kwargs, aggregator) — and each group's switcher column
    runs as lanes of one ``run_dynabro_scan_sweep`` call: one compiled
    driver dispatch per group instead of per cell, equivalent numerics
    (``tests/test_scenarios.py`` locks rows to the per-cell loop — exact
    round logs, floats within the parity suite's 1e-6). Rows come back in
    input order; duplicate scenarios are just duplicate lanes. ``wall_s`` is
    the group wall clock amortized over its lanes."""
    groups: Dict[Tuple, List[int]] = {}
    for i, sc in enumerate(scenarios):
        key = (sc.attack, sc.attack_kwargs, sc.aggregator)
        groups.setdefault(key, []).append(i)
    rows: List[Any] = [None] * len(scenarios)
    sampler = task.make_sampler(m)
    for idxs in groups.values():
        cfg = _cell_cfg(scenarios[idxs[0]], m, T, V, kappa, j_cap, use_mlmc,
                        delta)
        switchers = [get_switcher(scenarios[i].switcher, m, seed=seed,
                                  **dict(scenarios[i].switcher_kwargs))
                     for i in idxs]
        t0 = time.perf_counter()
        outs = run_dynabro_scan_sweep(task.grad_fn, task.params0, make_opt(),
                                      cfg, switchers, sampler, T, seed=seed,
                                      chunk=chunk)
        jax.block_until_ready(
            [l for p, _ in outs for l in jax.tree.leaves(p)])
        wall = (time.perf_counter() - t0) / max(len(idxs), 1)
        for i, (params, logs) in zip(idxs, outs):
            rows[i] = _row(task, scenarios[i], params, logs, driver="vmap",
                           m=m, T=T, wall=wall)
    return rows


def format_table(rows: Sequence[Dict[str, Any]], value: str = "final",
                 row_key: str = "aggregator", col_key: str = "attack") -> str:
    """Pivot a results table for terminal display (one line per row_key)."""
    cols = list(dict.fromkeys(r[col_key] for r in rows))
    lines = [f"{'':12s}" + "".join(f"{c:>12s}" for c in cols)]
    for rk in dict.fromkeys(r[row_key] for r in rows):
        cells = []
        for c in cols:
            sel = [r[value] for r in rows if r[row_key] == rk and r[col_key] == c]
            cells.append(f"{sel[0]:12.4f}" if sel else f"{'—':>12s}")
        lines.append(f"{rk:12s}" + "".join(cells))
    return "\n".join(lines)
