"""Pytree checkpointing: flattened key-path -> array, stored as .npz + a
treedef fingerprint. Gathers device arrays to host; restore preserves dtypes.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = np.asarray(jax.numpy.asarray(leaf).astype(jax.numpy.float32))
        out[key] = arr
    return out


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    meta = {"step": step, "keys": sorted(flat.keys())}
    with open(path.removesuffix(".npz") + ".json", "w") as f:
        json.dump(meta, f)


def checkpoint_step(path: str) -> int:
    """The round recorded in a checkpoint's ``.json`` meta — what the serve
    resume path uses to know where a saved carry left off."""
    with open(path.removesuffix(".npz") + ".json") as f:
        return int(json.load(f)["step"])


def latest_checkpoint(directory: str, prefix: str = ""):
    """``(path, step)`` of the highest-step checkpoint under ``directory``
    (basename filtered by ``prefix``), or None if there is none. A checkpoint
    is the ``.npz``/``.json`` pair ``save_checkpoint`` writes; a lone half of
    a pair (a kill mid-write) is skipped rather than trusted."""
    best = None
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return None
    for name in names:
        if not name.endswith(".json") or not name.startswith(prefix):
            continue
        base = os.path.join(directory, name.removesuffix(".json"))
        if not os.path.exists(base + ".npz"):
            continue
        try:
            step = checkpoint_step(base)
        except (OSError, ValueError, KeyError):
            continue
        if best is None or step > best[1]:
            best = (base, step)
    return best


def load_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
