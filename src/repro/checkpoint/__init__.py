from repro.checkpoint.checkpoint import (
    checkpoint_step, latest_checkpoint, load_checkpoint, save_checkpoint,
)

__all__ = ["save_checkpoint", "load_checkpoint", "checkpoint_step",
           "latest_checkpoint"]
