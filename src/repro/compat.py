"""Single source of truth for legacy-jax detection.

``jax.shard_map`` appeared in the same release window in which XLA learned to
lower collectives, ``axis_index``, while loops and gather/scatter inside a
*partial*-manual shard_map region — so its absence is the proxy every
legacy-path workaround keys on (DESIGN.md §3). Keep the predicate here:
mixing legacy and new-path code (e.g. unrolled scans without psum-emulated
gathers) reintroduces the partial-manual compile crashes piecemeal.
"""
import jax

LEGACY_PARTIAL_MANUAL = not hasattr(jax, "shard_map")
