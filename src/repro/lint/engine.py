"""The jaxlint engine: file walking, suppression pragmas, rule dispatch.

Pure stdlib (``ast`` + ``tokenize``) — the CI lint job runs ``python -m
repro.lint`` in a venv without jax installed, so nothing in the engine or
the rules may import jax (the runtime sanitizers live in
``repro.lint.runtime`` and import jax lazily).

Suppression syntax, line-scoped::

    self.cap = count_floor(x)  # jaxlint: disable=JXL003 -- sanctioned helper

    # jaxlint: disable=JXL004 -- wall clock feeds a results row, not a seed
    t0 = time.perf_counter()

A pragma suppresses the named rules on its own line and on the line
directly below it (the own-line-comment form). A pragma without a
``-- reason`` trailer is itself a violation (JXL000) — suppressions are
justifications, not mutes — and JXL000 cannot be suppressed.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*jaxlint:\s*disable=([A-Z0-9,\s]+?)\s*(?:--\s*(\S.*))?$"
)

BAD_SUPPRESS = "JXL000"


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule hit: ``path:line:col: RULE message``."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def _suppressions(source: str) -> Tuple[Dict[int, Set[str]], List[Violation]]:
    """Map line number -> rule codes suppressed there, plus JXL000 hits for
    reason-less pragmas. A pragma covers its own line and the next line."""
    by_line: Dict[int, Set[str]] = {}
    bad: List[Violation] = []
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = SUPPRESS_RE.search(text)
        if not m:
            continue
        codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
        if not m.group(2):
            bad.append(
                Violation(
                    BAD_SUPPRESS,
                    "",
                    lineno,
                    m.start(),
                    "suppression pragma without a '-- <reason>' trailer; "
                    "justify the disable or remove it",
                )
            )
            continue
        for covered in (lineno, lineno + 1):
            by_line.setdefault(covered, set()).update(codes)
    return by_line, bad


def lint_source(
    source: str,
    path: str = "<string>",
    select: Optional[Iterable[str]] = None,
) -> List[Violation]:
    """Lint one source string; ``path`` scopes the path-sensitive rules
    (e.g. JXL004's wall-clock check only fires in deterministic layers)."""
    from repro.lint.rules import RULES

    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [
            Violation(
                "JXL999",
                path,
                e.lineno or 1,
                e.offset or 0,
                f"file does not parse: {e.msg}",
            )
        ]
    suppressed, bad_pragmas = _suppressions(source)
    wanted = set(select) if select is not None else None
    out: List[Violation] = [
        dataclasses.replace(v, path=path)
        for v in bad_pragmas
        if wanted is None or BAD_SUPPRESS in wanted
    ]
    for code, rule in sorted(RULES.items()):
        if wanted is not None and code not in wanted:
            continue
        for node, message in rule.check(tree, path):
            line = getattr(node, "lineno", 1)
            col = getattr(node, "col_offset", 0)
            if code in suppressed.get(line, ()):
                continue
            out.append(Violation(code, path, line, col, message))
    out.sort(key=lambda v: (v.line, v.col, v.rule))
    return out


def iter_py_files(paths: Sequence[str]) -> Iterable[str]:
    """Yield .py files under each path (a file or a directory), skipping
    bytecode caches and hidden directories."""
    for p in paths:
        if os.path.isfile(p):
            yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(
                d for d in dirs if d != "__pycache__" and not d.startswith(".")
            )
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def lint_paths(
    paths: Sequence[str],
    select: Optional[Iterable[str]] = None,
) -> List[Violation]:
    out: List[Violation] = []
    for path in iter_py_files(paths):
        try:
            with open(path, encoding="utf-8") as f:
                source = f.read()
        except (OSError, UnicodeDecodeError) as e:
            out.append(Violation("JXL999", path, 1, 0, f"unreadable: {e}"))
            continue
        out.extend(lint_source(source, path=path, select=select))
    return out
