"""Runtime sanitizers: the recompile guard and the NaN/Inf tripwire.

Static analysis catches hazards visible in source; these catch the two
that only manifest at run time — silent recompilation churn (a 10x
steady-state slowdown that looks like "jax is slow") and non-finite
aggregates propagating through a robust rule that is supposed to bound
them.

``recompile_guard`` counts XLA backend compiles via ``jax.monitoring``'s
event-duration stream (one ``.../backend_compile_duration`` event per
actual compile; cache hits emit nothing — verified on jax 0.4.37 and
current). The listener is process-global and installed once; guards read
before/after deltas, so nesting and threads both work (a compile on any
thread inside the window counts — the serve consumer drives the jitted
step from its worker thread).

jax is imported lazily so ``repro.lint``'s static side stays importable
from the jax-less CI lint venv.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
from typing import Iterator, Optional

_LOCK = threading.Lock()
_COMPILES = 0
_INSTALLED = False


class RecompileError(AssertionError):
    """A guarded steady-state region recompiled."""


def _on_event(event: str, duration: float, **kw) -> None:
    global _COMPILES
    if "backend_compile" in event:
        with _LOCK:
            _COMPILES += 1


def install_compile_counter() -> None:
    """Idempotently hook the process-global compile counter into
    ``jax.monitoring``. Called by ``recompile_guard``; call it early (before
    warmup) if you want ``compile_count()`` to cover warmup compiles too."""
    global _INSTALLED
    with _LOCK:
        if _INSTALLED:
            return
        _INSTALLED = True
    from jax import monitoring

    monitoring.register_event_duration_secs_listener(_on_event)


def compile_count() -> int:
    """Backend compiles observed since ``install_compile_counter``."""
    with _LOCK:
        return _COMPILES


@dataclasses.dataclass
class GuardStats:
    """Filled in when the guarded block exits: ``count`` is the number of
    backend compiles that happened inside the window."""

    label: str
    count: int = 0


@contextlib.contextmanager
def recompile_guard(
    label: str = "steady state",
    max_recompiles: int = 0,
    action: str = "raise",
) -> Iterator[GuardStats]:
    """Assert a warmed code region stays on the jit cache.

    ``action="raise"`` raises ``RecompileError`` when more than
    ``max_recompiles`` compiles land inside the block (the default, and the
    contract ``Session`` enforces in guarded mode); ``action="count"`` only
    records the delta in the yielded ``GuardStats`` — the benchmark mode,
    where the count becomes a gated CSV row instead of an exception. The
    count is recorded even when the block raises; the guard's own error is
    suppressed then (never mask the original failure).
    """
    if action not in ("raise", "count"):
        raise ValueError(f"unknown action {action!r}; expected raise|count")
    install_compile_counter()
    stats = GuardStats(label)
    start = compile_count()
    try:
        yield stats
    except BaseException:
        stats.count = compile_count() - start
        raise
    stats.count = compile_count() - start
    if action == "raise" and stats.count > max_recompiles:
        raise RecompileError(
            f"{label}: {stats.count} recompile(s) in a steady-state region "
            f"(allowed {max_recompiles}) — a shape/dtype/static-arg is "
            f"changing between calls"
        )


# ------------------------------------------------------------ NaN tripwire

TRIPWIRE_ENV = "REPRO_NAN_TRIPWIRE"


def assert_all_finite(tree, label: str = "aggregate") -> None:
    """Host-side NaN/Inf tripwire over a pytree of arrays; raises
    ``FloatingPointError`` naming the offending leaf path."""
    import jax
    import numpy as np

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fc":
            continue
        if not np.isfinite(arr).all():
            bad = int((~np.isfinite(arr)).sum())
            raise FloatingPointError(
                f"{label}: {bad} non-finite value(s) at leaf "
                f"{jax.tree_util.keystr(path) or '<root>'}"
            )


def tripwire_enabled(explicit: Optional[bool] = None) -> bool:
    """The tripwire's opt-in: an explicit flag wins, else the
    ``REPRO_NAN_TRIPWIRE`` env var ('1'/'true'/'on')."""
    if explicit is not None:
        return explicit
    return os.environ.get(TRIPWIRE_ENV, "").lower() in ("1", "true", "on")


def maybe_assert_finite(
    tree, label: str = "aggregate", enabled: Optional[bool] = None
) -> None:
    if tripwire_enabled(enabled):
        assert_all_finite(tree, label)
