"""``python -m repro.lint [--check] [paths...]`` — the jaxlint CLI.

Default paths are the repo's checked trees (``src``, ``benchmarks``,
``examples``), resolved relative to the repository root (three levels above
this file), so CI and local runs agree regardless of cwd. Exit code 1 on
any violation; ``--check`` is the explicit CI spelling of the same
contract. Imports no jax — runnable from the ruff-only lint venv.
"""

from __future__ import annotations

import argparse
import os
import sys

from repro.lint.engine import lint_paths
from repro.lint.rules import RULES

REPO_ROOT = os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
)
DEFAULT_TREES = ("src", "benchmarks", "examples")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="JAX-aware static analysis (DESIGN.md §11)",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help=f"files/dirs to lint (default: {' '.join(DEFAULT_TREES)})",
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="CI mode: identical to the default, spelled as a gate",
    )
    ap.add_argument(
        "--select",
        default=None,
        help="comma-separated rule codes to run (default: all)",
    )
    ap.add_argument("--list-rules", action="store_true", help="print the rule table")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code, r in sorted(RULES.items()):
            print(f"{code}  {r.summary}")
        return 0

    paths = args.paths or [
        p
        for p in (os.path.join(REPO_ROOT, t) for t in DEFAULT_TREES)
        if os.path.exists(p)
    ]
    select = args.select.split(",") if args.select else None
    violations = lint_paths(paths, select=select)
    for v in violations:
        print(v.render())
    n = len(violations)
    print(f"jaxlint: {n} violation(s)" if n else "jaxlint: clean")
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main())
