"""The jaxlint rule registry: JAX hazards this codebase has actually hit.

Each rule is an AST checker registered under a ``JXL00x`` code (DESIGN.md
§11 has the rule table and each rule's motivating historical bug). Rules
yield ``(node, message)`` pairs; the engine applies suppressions and
formats. Everything here is stdlib-only — see the engine docstring.

The traced-context analysis is deliberately heuristic: it looks for
functions that are *known* to be traced (jit/vmap/grad-decorated, or passed
by name into ``jax.lax`` control flow / ``jax.jit`` / ``shard_map`` /
``pallas_call``) and taints their parameters. Names derived from
``.shape`` / ``.ndim`` / ``.dtype`` / ``.size`` attributes, ``is None``
pytree-structure checks, and ``static_argnames`` parameters are exempt —
those are the host-static escape hatches tracing supports.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Set, Tuple

RuleHit = Tuple[ast.AST, str]


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    summary: str
    check: Callable[[ast.Module, str], Iterator[RuleHit]]


RULES: Dict[str, Rule] = {}


def rule(code: str, summary: str):
    def register(fn: Callable[[ast.Module, str], Iterator[RuleHit]]) -> Rule:
        r = Rule(code, summary, fn)
        RULES[code] = r
        return r

    return register


# --------------------------------------------------------------- shared AST


def _attr_chain(node: ast.AST) -> List[str]:
    """``jax.lax.scan`` -> ["jax", "lax", "scan"]; [] for non-name chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return []


def _call_name(call: ast.Call) -> str:
    chain = _attr_chain(call.func)
    return chain[-1] if chain else ""


# transforms whose function-valued arguments run under a tracer
TRANSFORMS = {
    "jit",
    "vmap",
    "pmap",
    "grad",
    "value_and_grad",
    "shard_map",
    "pallas_call",
    "checkify",
    "custom_vjp",
    "custom_jvp",
    "scan",
    "cond",
    "switch",
    "while_loop",
    "fori_loop",
    "associative_scan",
    "remat",
    "checkpoint",
}

STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "sharding"}


def _static_names(call: ast.Call) -> Set[str]:
    """Extract ``static_argnames=`` parameter names from a jit-like call."""
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            out.add(v.value)
        elif isinstance(v, (ast.Tuple, ast.List)):
            for el in v.elts:
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    out.add(el.value)
    return out


@dataclasses.dataclass
class TracedFn:
    node: ast.AST  # FunctionDef | AsyncFunctionDef
    static_params: Set[str]
    via: str  # how we know it's traced, for messages


def _params(fn) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _transform_target(call: ast.Call) -> Optional[str]:
    """The transform name if this call IS a transform application (including
    ``functools.partial(jax.jit, ...)``), else None."""
    name = _call_name(call)
    if name in TRANSFORMS:
        return name
    if name == "partial" and call.args:
        inner = call.args[0]
        chain = _attr_chain(inner)
        if chain and chain[-1] in TRANSFORMS:
            return chain[-1]
    return None


def find_traced_functions(tree: ast.Module) -> List[TracedFn]:
    """Functions known to run under a tracer: transform-decorated, or passed
    by (bare) name into a transform call; nested defs inherit tracedness."""
    defs: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)

    traced: Dict[int, TracedFn] = {}

    def mark(fn: ast.AST, via: str, static: Set[str]) -> None:
        if id(fn) in traced:
            traced[id(fn)].static_params |= static
        else:
            traced[id(fn)] = TracedFn(fn, static, via)

    # 1. decorator form: @jit / @partial(jax.jit, static_argnames=...)
    for name, nodes in defs.items():
        for node in nodes:
            for dec in node.decorator_list:
                chain = _attr_chain(dec)
                if chain and chain[-1] in TRANSFORMS:
                    mark(node, f"@{chain[-1]}", set())
                elif isinstance(dec, ast.Call):
                    target = _transform_target(dec)
                    if target is not None:
                        mark(node, f"@{target}", _static_names(dec))

    # 2. call-site form: lax.scan(body, ...), jax.jit(step, ...),
    #    lax.switch(i, [f, g], ...)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        target = _transform_target(node)
        if target is None:
            continue
        static = _static_names(node)
        cands: List[ast.AST] = list(node.args)
        for arg in node.args:
            if isinstance(arg, (ast.List, ast.Tuple)):  # lax.switch branches
                cands.extend(arg.elts)
        for arg in cands:
            if isinstance(arg, ast.Name):
                for fn in defs.get(arg.id, ()):
                    mark(fn, f"passed to {target}", static)

    # 3. defs nested inside traced functions trace with their parent
    changed = True
    while changed:
        changed = False
        for tf in list(traced.values()):
            for inner in ast.walk(tf.node):
                if (
                    isinstance(inner, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and inner is not tf.node
                    and id(inner) not in traced
                ):
                    traced[id(inner)] = TracedFn(
                        inner, set(), f"nested in traced {tf.node.name}"
                    )
                    changed = True
    return list(traced.values())


# ----------------------------------------------------------- taint analysis


def _is_none_check(node: ast.AST) -> bool:
    """``x is None`` / ``x is not None`` — the pytree-structure branch form
    jit supports (structure is static), never a tracer leak."""
    return isinstance(node, ast.Compare) and all(
        isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops
    )


_UNTAINT_CALLS = {"len", "isinstance", "type", "range", "enumerate", "zip"}


class Taint:
    """Which local names derive from traced parameters, by forward
    propagation through the statement list (two passes, for loops)."""

    def __init__(self, fn, static_params: Set[str]):
        self.tainted: Set[str] = {
            p for p in _params(fn) if p not in static_params and p != "self"
        }

    def expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            return self.expr(node.value)
        if isinstance(node, ast.Call):
            if _call_name(node) in _UNTAINT_CALLS:
                return False
            recv = (  # method receiver: x.sum() taints through x
                self.expr(node.func.value)
                if isinstance(node.func, ast.Attribute)
                else False
            )
            return (
                recv
                or any(self.expr(a) for a in node.args)
                or any(self.expr(kw.value) for kw in node.keywords)
            )
        if _is_none_check(node):
            return False
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.expr, ast.Starred)) and self.expr(child):
                return True
        return False

    def _assign_targets(self, target: ast.AST, value_tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if value_tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._assign_targets(el, value_tainted)
        elif isinstance(target, ast.Starred):
            self._assign_targets(target.value, value_tainted)

    def propagate(self, fn) -> None:
        for _ in range(2):  # second pass fixes loop-carried taint
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign):
                    t = self.expr(node.value)
                    for target in node.targets:
                        self._assign_targets(target, t)
                elif isinstance(node, ast.AnnAssign) and node.value is not None:
                    self._assign_targets(node.target, self.expr(node.value))
                elif isinstance(node, ast.AugAssign):
                    if self.expr(node.value):
                        self._assign_targets(node.target, True)
                elif isinstance(node, ast.For):
                    self._assign_targets(node.target, self.expr(node.iter))
                elif isinstance(node, ast.withitem) and node.optional_vars:
                    self._assign_targets(
                        node.optional_vars, self.expr(node.context_expr)
                    )


def _traced_contexts(tree: ast.Module):
    for tf in find_traced_functions(tree):
        taint = Taint(tf.node, tf.static_params)
        taint.propagate(tf.node)
        yield tf, taint


def _walk_own(fn) -> Iterator[ast.AST]:
    """Walk a function body without descending into nested defs (those are
    their own traced contexts, with their own parameters)."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


# ------------------------------------------------------------------- JXL001


_KEY_MAKERS = {"PRNGKey", "key", "split", "fold_in", "clone"}
_KEY_DERIVERS = {"split", "fold_in", "clone", "key_data", "wrap_key_data"}
_RANDOM_CONSUMERS = {
    "normal",
    "uniform",
    "bernoulli",
    "randint",
    "bits",
    "permutation",
    "choice",
    "categorical",
    "gumbel",
    "laplace",
    "exponential",
    "truncated_normal",
    "poisson",
    "gamma",
    "beta",
    "dirichlet",
    "rademacher",
    "cauchy",
    "orthogonal",
    "ball",
    "t",
    "dropout",
}


def _is_key_name(name: str) -> bool:
    low = name.lower()
    return low == "rng" or low.endswith("key") or low.endswith("keys")


@rule("JXL001", "PRNG key consumed more than once without split/fold_in")
def jxl001(tree: ast.Module, path: str) -> Iterator[RuleHit]:
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        key_vars: Set[str] = {p for p in _params(fn) if _is_key_name(p)}
        for node in _walk_own(fn):
            if isinstance(node, ast.Assign):
                if (
                    isinstance(node.value, ast.Call)
                    and _call_name(node.value) in _KEY_MAKERS
                ):
                    for target in node.targets:
                        for el in (
                            target.elts
                            if isinstance(target, (ast.Tuple, ast.List))
                            else [target]
                        ):
                            if isinstance(el, ast.Name):
                                key_vars.add(el.id)
        if not key_vars:
            continue

        uses: Dict[str, List[ast.AST]] = {}
        loops: List[ast.AST] = []

        def loop_guard(name: str, loop: ast.AST) -> bool:
            """True when ``name`` is re-derived per iteration: it is a loop
            target, or (re)assigned somewhere in the loop body."""
            targets = loop.target if isinstance(loop, ast.For) else None
            names: Set[str] = set()
            if targets is not None:
                for el in ast.walk(targets):
                    if isinstance(el, ast.Name):
                        names.add(el.id)
            if name in names:
                return True
            for sub in ast.walk(loop):
                if isinstance(sub, ast.Assign):
                    for target in sub.targets:
                        for el in ast.walk(target):
                            if isinstance(el, ast.Name) and el.id == name:
                                return True
            return False

        def record(name: str, site: ast.AST, weight: int) -> None:
            uses.setdefault(name, []).extend([site] * weight)

        def consume(call: ast.Call) -> None:
            fname = _call_name(call)
            if fname in _KEY_DERIVERS:
                return  # split/fold_in derive, they do not consume
            in_loop = [lp for lp in loops]
            args = [(None, a) for a in call.args] + [
                (kw.arg, kw.value) for kw in call.keywords
            ]
            for kwname, a in args:
                if not (isinstance(a, ast.Name) and a.id in key_vars):
                    continue
                if fname not in _RANDOM_CONSUMERS and kwname != "key":
                    continue
                weight = 1
                for lp in in_loop:
                    if not loop_guard(a.id, lp):
                        weight = 2  # same key every iteration
                record(a.id, call, weight)

        def visit(node: ast.AST) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return
            if isinstance(node, ast.If):
                # exclusive branches: only one side runs, so a key used once
                # in each arm is consumed once, not twice — keep the heavier
                # arm's uses
                visit(node.test)
                before = {k: list(v) for k, v in uses.items()}
                for stmt in node.body:
                    visit(stmt)
                after_body = {k: list(v) for k, v in uses.items()}
                uses.clear()
                uses.update({k: list(v) for k, v in before.items()})
                for stmt in node.orelse:
                    visit(stmt)
                for k in set(after_body) | set(uses):
                    body_sites = after_body.get(k, [])
                    if len(body_sites) > len(uses.get(k, [])):
                        uses[k] = body_sites
                return
            entered = isinstance(node, (ast.For, ast.While))
            if entered:
                loops.append(node)
            if isinstance(node, ast.Call):
                consume(node)
            if isinstance(node, ast.Assign):
                # reassignment re-derives: close the previous use window
                for target in node.targets:
                    for el in ast.walk(target):
                        if isinstance(el, ast.Name) and el.id in uses:
                            uses.pop(el.id, None)
            for child in ast.iter_child_nodes(node):
                visit(child)
            if entered:
                loops.pop()

        for stmt in fn.body:
            visit(stmt)
        for name, sites in uses.items():
            if len(sites) >= 2:
                yield (
                    sites[1],
                    f"PRNG key '{name}' is consumed {len(sites)}x in "
                    f"'{fn.name}' without an intervening split/fold_in — "
                    f"identical randomness at every use",
                )


# ------------------------------------------------------------------- JXL002


@rule("JXL002", "host-side branching on traced values inside traced code")
def jxl002(tree: ast.Module, path: str) -> Iterator[RuleHit]:
    for tf, taint in _traced_contexts(tree):
        for node in _walk_own(tf.node):
            if isinstance(node, (ast.If, ast.While)):
                test = node.test
                if _is_none_check(test):
                    continue
                if taint.expr(test):
                    kind = "while" if isinstance(node, ast.While) else "if"
                    yield (
                        test,
                        f"host `{kind}` on a traced value inside "
                        f"'{tf.node.name}' ({tf.via}) — this raises a "
                        f"TracerBoolConversionError or bakes one branch in "
                        f"at trace time; use lax.cond/jnp.where",
                    )
            elif isinstance(node, ast.Call):
                name = _call_name(node)
                if name in ("int", "float", "bool") and any(
                    taint.expr(a) for a in node.args
                ):
                    yield (
                        node,
                        f"`{name}()` on a traced value inside "
                        f"'{tf.node.name}' ({tf.via}) — forces a host "
                        f"round-trip per call (or fails under jit)",
                    )


# ------------------------------------------------------------------- JXL003


@rule("JXL003", "f64 host arithmetic feeding traced integer/count math")
def jxl003(tree: ast.Module, path: str) -> Iterator[RuleHit]:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if chain[:1] == ["math"] and chain[-1] in ("ceil", "floor", "trunc"):
            yield (
                node,
                f"math.{chain[-1]} on a float product picks up f64 "
                f"representation error at exact boundaries (the PR 4 "
                f"ceil() artifact); use agg_engine.count_ceil/count_floor",
            )
        elif (
            chain == ["int"]
            and len(node.args) == 1
            and isinstance(node.args[0], ast.BinOp)
            and isinstance(node.args[0].op, (ast.Mult, ast.Div))
        ):
            yield (
                node,
                "int() truncation of a float product/quotient — "
                "int(0.3 * 10) == 2; use agg_engine.count_floor (nudged) "
                "or an exact integer formula",
            )


# ------------------------------------------------------------------- JXL004


_DETERMINISTIC_PARTS = ("/core/", "/api/", "/data/", "/checkpoint", "/optim/")
_WALL_CLOCK = {"time", "time_ns", "now", "utcnow", "today"}
_SEEDLESS_NP_RANDOM = {
    "rand",
    "randn",
    "random",
    "randint",
    "random_integers",
    "random_sample",
    "choice",
    "permutation",
    "shuffle",
    "normal",
    "uniform",
    "standard_normal",
    "seed",
}


def _in_deterministic_layer(path: str) -> bool:
    p = path.replace("\\", "/")
    return any(part in p for part in _DETERMINISTIC_PARTS)


@rule("JXL004", "nondeterminism in schedule/replay paths")
def jxl004(tree: ast.Module, path: str) -> Iterator[RuleHit]:
    deterministic = _in_deterministic_layer(path)
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            name = chain[-1] if chain else ""
            if chain == ["hash"] and node.args:
                yield (
                    node,
                    "hash() is salted per process (PYTHONHASHSEED) — the "
                    "PR 5 flaky-seed bug; derive seeds from explicit "
                    "integers or fold_in",
                )
            elif (
                deterministic
                and len(chain) >= 2
                and chain[-2] == "time"
                and name in _WALL_CLOCK
            ):
                yield (
                    node,
                    f"time.{name}() in a deterministic layer — schedules "
                    f"and replay streams must be pure functions of "
                    f"(cfg, seed, T)",
                )
            elif (
                len(chain) >= 2
                and chain[-2] == "random"
                and chain[0] in ("np", "numpy")
                and name in _SEEDLESS_NP_RANDOM
            ):
                yield (
                    node,
                    f"seedless np.random.{name}() draws from global mutable "
                    f"state — use np.random.default_rng(seed)",
                )
            elif (
                name == "default_rng"
                and len(chain) >= 2
                and chain[-2] == "random"
                and not node.args
                and not node.keywords
            ):
                yield (
                    node,
                    "np.random.default_rng() without a seed is entropy-"
                    "seeded — pass an explicit seed",
                )
        elif isinstance(node, ast.For):
            it = node.iter
            is_set_iter = isinstance(it, (ast.Set, ast.SetComp)) or (
                isinstance(it, ast.Call) and _call_name(it) == "set"
            )
            if is_set_iter:
                yield (
                    it,
                    "iteration over a set — element order depends on the "
                    "per-process hash seed for str keys; sort it or use "
                    "dict.fromkeys for ordered dedup",
                )


# ------------------------------------------------------------------- JXL005


@rule("JXL005", "numpy/host ops on traced values inside scan/shard_map")
def jxl005(tree: ast.Module, path: str) -> Iterator[RuleHit]:
    for tf, taint in _traced_contexts(tree):
        for node in _walk_own(tf.node):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if chain and chain[0] in ("np", "numpy"):
                if any(taint.expr(a) for a in node.args) or any(
                    taint.expr(kw.value) for kw in node.keywords
                ):
                    yield (
                        node,
                        f"numpy call '{'.'.join(chain)}' on a traced value "
                        f"inside '{tf.node.name}' ({tf.via}) — forces a "
                        f"device sync per trace (or a TracerArrayConversion"
                        f"Error); use jnp",
                    )
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in ("item", "tolist", "to_py")
                and taint.expr(node.func.value)
            ):
                yield (
                    node,
                    f".{node.func.attr}() on a traced value inside "
                    f"'{tf.node.name}' ({tf.via}) — host materialization "
                    f"in traced code",
                )


# ------------------------------------------------------------------- JXL006


def _enclosing_scopes(tree: ast.Module) -> Dict[ast.AST, ast.AST]:
    """Map each node to its nearest enclosing function (module as fallback)."""
    scope_of: Dict[ast.AST, ast.AST] = {}

    def visit(node: ast.AST, scope: ast.AST) -> None:
        scope_of[node] = scope
        child_scope = (
            node
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
            else scope
        )
        for child in ast.iter_child_nodes(node):
            visit(child, child_scope)

    visit(tree, tree)
    return scope_of


def _mentions_n_seeds(scope: ast.AST) -> bool:
    for node in ast.walk(scope):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            if "n_seeds" in node.value:
                return True
        elif isinstance(node, ast.Name) and node.id == "n_seeds":
            return True
        elif isinstance(node, ast.Attribute) and node.attr == "n_seeds":
            return True
    return False


@rule("JXL006", "'+-' spread formatted with no n_seeds handling in scope")
def jxl006(tree: ast.Module, path: str) -> Iterator[RuleHit]:
    """An f-string that renders ``...+-{spread}`` (or ``±``) is an error bar.

    Error bars computed from a length-1 sample print ``+-0.000`` — typography
    masquerading as statistics (the ISSUE-10 reporting bug: fast-mode bench
    rows ran one seed and still printed a spread). A formatter that handles
    the degenerate case necessarily talks about ``n_seeds`` somewhere in the
    same function (to branch on it or to report it alongside); one that never
    mentions it cannot be guarding, so flag it."""
    scope_of = _enclosing_scopes(tree)
    guarded: Dict[ast.AST, bool] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.JoinedStr):
            continue
        parts = node.values
        for lit, nxt in zip(parts, parts[1:]):
            if not (
                isinstance(lit, ast.Constant)
                and isinstance(lit.value, str)
                and (lit.value.endswith("+-") or lit.value.endswith("±"))
                and isinstance(nxt, ast.FormattedValue)
            ):
                continue
            scope = scope_of.get(node, tree)
            if scope not in guarded:
                guarded[scope] = _mentions_n_seeds(scope)
            if guarded[scope]:
                continue
            yield (
                node,
                "f-string renders a '+-' spread but the enclosing scope "
                "never mentions n_seeds — a single-seed sample prints a "
                "fake '+-0.000' error bar; carry n_seeds in the output and "
                "omit the spread when n_seeds == 1",
            )
            break
