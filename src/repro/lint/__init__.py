"""repro.lint — JAX-aware static analysis + runtime sanitizers (DESIGN.md §11).

Two halves, one import surface:

* the static pass (``engine`` / ``rules`` / ``python -m repro.lint``):
  stdlib-only, importable without jax, so the CI lint job can gate it from
  the ruff venv;
* the runtime sanitizers (``runtime``): ``recompile_guard``, the compile
  counter and the NaN/Inf tripwire — these need jax and are re-exported
  lazily so importing ``repro.lint`` never pulls it in.
"""

from __future__ import annotations

from repro.lint.engine import Violation, lint_paths, lint_source  # noqa: F401

_RUNTIME = (
    "GuardStats",
    "RecompileError",
    "assert_all_finite",
    "compile_count",
    "install_compile_counter",
    "maybe_assert_finite",
    "recompile_guard",
    "tripwire_enabled",
)

__all__ = ["Violation", "lint_paths", "lint_source", *_RUNTIME]


def __getattr__(name: str):
    if name in _RUNTIME:
        from repro.lint import runtime

        return getattr(runtime, name)
    raise AttributeError(f"module 'repro.lint' has no attribute {name!r}")
