"""Jittable step builders for every (arch × shape) combination.

* ``build_train_step``   — Mode-B DynaBRO robust training step: FSDP params,
  partial-manual shard_map (manual over worker axes, auto over 'model'),
  robust-aggregating custom-VJP gathers, simulated Byzantine mask input.
  MLMC level j is expressed as a 2^j× larger per-worker batch (a mini-batch
  gradient of 2^j unit batches IS the level-j gradient — see DESIGN.md §3),
  so the aggregation applies to worker *means* exactly as in Algorithm 2.
* ``build_prefill_step`` — inference prefill (logits + cache).
* ``build_decode_step``  — one token against a seq_len KV cache.

Each returns (jitted_fn, example_inputs) where example_inputs are
ShapeDtypeStructs with NamedShardings — ready for ``.lower().compile()``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.sharded import ShardedByzConfig, make_param_hook
from repro.launch import sharding as shl
from repro.launch.mesh import (
    n_workers, shard_map, worker_axes, worker_iota, worker_spec,
)
from repro.models import loss_fn, decode_step, prefill
from repro.models import scan_compat

# jax <= 0.4.x: model scans inside the Mode B partial-manual region must
# unroll, including custom-VJP backward scans traced during the grad sweep —
# hence the flag wraps the whole local step, not just forward (DESIGN.md §3).
from repro.compat import LEGACY_PARTIAL_MANUAL as _LEGACY_PARTIAL_MANUAL
from repro.optim.optimizers import Optimizer, apply_updates, sgd


# spec/SDS helpers live in launch.sharding now (shared with the scan driver)
_sds = shl.sds


@dataclasses.dataclass
class BuiltStep:
    fn: Any  # jitted callable
    inputs: Tuple  # ShapeDtypeStruct pytrees (positional)
    name: str


def _perf_cfg(cfg: ModelConfig, mesh: Mesh) -> ModelConfig:
    """Per-mesh perf knobs (§Perf). Env overrides allow A/B dry-runs:
    REPRO_ATTN_IMPL=chunked REPRO_MOE_GROUP=0 reproduces the baseline."""
    ms = mesh.shape["model"]
    impl = os.environ.get("REPRO_ATTN_IMPL", cfg.attn_impl)
    seq_shard = ""
    if impl == "flash" and not (cfg.n_heads % ms == 0 and cfg.n_kv_heads % ms == 0):
        # heads don't divide the model axis: shard the q-sequence dim instead
        seq_shard = os.environ.get("REPRO_ATTN_SEQ_SHARD", "model")
    tg = int(os.environ.get("REPRO_MOE_GROUP", str(cfg.moe_token_group)))
    es = ""
    if cfg.is_moe and cfg.n_experts % ms == 0 and impl == "flash":
        es = os.environ.get("REPRO_MOE_EXPERT_SHARD", "model")
    return dataclasses.replace(cfg, attn_impl=impl, attn_seq_shard=seq_shard,
                               moe_token_group=tg, moe_expert_shard=es)


# ================================================================ train


@dataclasses.dataclass
class _TrainPlumbing:
    """Everything the two Mode-B train-step builders share — ONE spec / jit /
    ShapeDtypeStruct pipeline (DESIGN.md §9), so the plain-DynaBRO and MLMC
    steps cannot drift again (the old duplicated ~60 lines dropped the
    audio/vlm ``extra`` batch leaves from the MLMC path)."""
    cfg: ModelConfig
    byz: ShardedByzConfig
    specs: Any
    plans: dict
    opt: Optimizer
    ospecs: Any
    opt_state_shapes: Any
    batch_spec: Any
    batch_ex: Any
    waxes: Tuple[str, ...]
    m: int
    dtype: Any


def _train_plumbing(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                    level_units: int, aggregator: str, attack: str,
                    delta: float, opt: Optional[Optimizer], lr: float,
                    agg_backend: str, dtype) -> _TrainPlumbing:
    cfg = _perf_cfg(cfg, mesh)
    waxes = worker_axes(mesh)
    m = n_workers(mesh)
    B = shape.global_batch * level_units
    if B % m:
        raise ValueError(
            f"global batch {B} not divisible by m={m} workers — Mode B "
            f"shards the batch over the worker axes")
    byz = ShardedByzConfig(axis_names=waxes, m=m, aggregator=aggregator,
                           delta=delta, attack=attack, backend=agg_backend)
    specs, plans = shl.plan_params(cfg, mesh, fsdp=True, dtype=dtype)
    opt = opt or sgd(lr)
    batch_spec, batch_ex = shl.batch_sds(cfg, mesh, B, shape.seq_len,
                                         kind="train", dtype=dtype)
    opt_state_shapes = jax.eval_shape(
        lambda: opt.init(shl.abstract_params(cfg, dtype)))
    ospecs = shl.opt_specs(opt_state_shapes, specs)
    return _TrainPlumbing(cfg, byz, specs, plans, opt, ospecs,
                          opt_state_shapes, batch_spec, batch_ex, waxes, m,
                          dtype)


def _wrap_train_step(pl: _TrainPlumbing, step_local, mesh: Mesh, aux_spec,
                     name: str) -> BuiltStep:
    """shard_map + jit + example-input assembly shared by both builders."""
    pspecs_manual = shl.strip_model(pl.specs)
    ospecs_manual = shl.strip_model(pl.ospecs)
    smapped = shard_map(
        step_local, mesh=mesh,
        in_specs=(pspecs_manual, ospecs_manual, pl.batch_spec, P(None),
                  P(worker_spec(pl.waxes))),
        out_specs=(pspecs_manual, ospecs_manual, aux_spec),
        axis_names=set(pl.waxes), check_vma=False)

    def stepped(params, opt_state, batch, maskf):
        # worker-index iota: sharding over the worker axes hands each device
        # its own flattened index as data (see core.sharded.make_param_hook)
        return smapped(params, opt_state, batch, maskf, worker_iota(pl.m))

    jitted = jax.jit(
        stepped,
        in_shardings=(shl.named(mesh, pl.specs), shl.named(mesh, pl.ospecs),
                      shl.named(mesh, pl.batch_spec),
                      NamedSharding(mesh, P(None))),
        out_shardings=(shl.named(mesh, pl.specs), shl.named(mesh, pl.ospecs),
                       None),
        donate_argnums=(0, 1))
    params_in = shl.sds_tree(shl.abstract_params(pl.cfg, pl.dtype), pl.specs,
                             mesh)
    opt_in = shl.sds_tree(pl.opt_state_shapes, pl.ospecs, mesh)
    maskf = shl.sds((pl.m,), jnp.float32, mesh, P(None))
    return BuiltStep(jitted, (params_in, opt_in, pl.batch_ex, maskf), name)


def build_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                     *, aggregator: str = "cwmed", attack: str = "none",
                     level: int = 0, lr: float = 1e-3, delta: float = 0.25,
                     opt: Optional[Optimizer] = None, agg_backend: str = "auto",
                     dtype=jnp.bfloat16) -> BuiltStep:
    pl = _train_plumbing(cfg, mesh, shape, level_units=2 ** level,
                         aggregator=aggregator, attack=attack, delta=delta,
                         opt=opt, lr=lr, agg_backend=agg_backend, dtype=dtype)
    cfg = pl.cfg

    def step_local(params, opt_state, batch, maskf, widx):
        with scan_compat.unrolled_scans(_LEGACY_PARTIAL_MANUAL):
            hook = make_param_hook(pl.byz, pl.plans, maskf, widx)
            loss, g = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg, param_hook=hook))(params)
        updates, opt_state = pl.opt.update(g, opt_state, params)
        params = apply_updates(params, updates)
        loss = jax.lax.pmean(loss, pl.waxes)
        return params, opt_state, loss

    return _wrap_train_step(pl, step_local, mesh, P(),
                            f"train[{cfg.arch_id}/{shape.name}/l{level}]")


# ================================================================ inference


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                       dtype=jnp.bfloat16) -> BuiltStep:
    cfg = _perf_cfg(cfg, mesh)
    if shape.global_batch % mesh.shape["data"] == 0:
        cfg = dataclasses.replace(cfg, attn_batch_shard="data")
    specs, _ = shl.plan_params(cfg, mesh, fsdp=_infer_fsdp(cfg, mesh), dtype=dtype)
    B, S = shape.global_batch, shape.seq_len
    bspec = shl.batch_specs(cfg, mesh, B, "prefill")

    def fn(params, tokens, extra):
        return prefill(params, tokens, cfg, extra=extra)

    jitted = jax.jit(fn, in_shardings=(shl.named(mesh, specs),
                                       NamedSharding(mesh, bspec["tokens"]),
                                       shl.named(mesh, bspec.get("extra", {}))),
                     out_shardings=None)
    params_in = shl.sds_tree(shl.abstract_params(cfg, dtype), specs, mesh)
    tokens = _sds((B, S), jnp.int32, mesh, bspec["tokens"])
    extra = {}
    if cfg.family == "audio":
        extra = {"frames": _sds((B, cfg.encoder_seq, cfg.d_model), dtype, mesh,
                                bspec["extra"]["frames"])}
    if cfg.family == "vlm":
        extra = {"patches": _sds((B, cfg.n_image_tokens, cfg.d_model), dtype, mesh,
                                 bspec["extra"]["patches"])}
    return BuiltStep(jitted, (params_in, tokens, extra),
                     f"prefill[{cfg.arch_id}/{shape.name}]")


def build_decode_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                      dtype=jnp.bfloat16) -> BuiltStep:
    cfg = _perf_cfg(cfg.for_shape(shape), mesh)
    specs, _ = shl.plan_params(cfg, mesh, fsdp=_infer_fsdp(cfg, mesh), dtype=dtype)
    B, S = shape.global_batch, shape.seq_len
    cache_shapes, cache_specs = shl.cache_spec_tree(cfg, mesh, B, S)
    tok_spec = P("data") if B % mesh.shape["data"] == 0 else P(None)

    def fn(params, cache, token, pos):
        return decode_step(params, cache, token, pos, cfg)

    jitted = jax.jit(fn, in_shardings=(shl.named(mesh, specs),
                                       shl.named(mesh, cache_specs),
                                       NamedSharding(mesh, tok_spec),
                                       NamedSharding(mesh, P())),
                     out_shardings=None,
                     donate_argnums=(1,))
    params_in = shl.sds_tree(shl.abstract_params(cfg, dtype), specs, mesh)
    cache_in = shl.sds_tree(cache_shapes, cache_specs, mesh)
    token = _sds((B,), jnp.int32, mesh, tok_spec)
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    return BuiltStep(jitted, (params_in, cache_in, token, pos),
                     f"decode[{cfg.arch_id}/{shape.name}]")


def _infer_fsdp(cfg: ModelConfig, mesh: Mesh) -> bool:
    """Inference: FSDP the weights too once model-parallel alone would not fit
    comfortably (~> 4 GB/chip of the 16 GB v5e HBM)."""
    return cfg.param_count() * 2 / mesh.shape["model"] > 4e9


def build_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, **kw) -> BuiltStep:
    cfg = cfg.for_shape(shape)
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape)
    return build_decode_step(cfg, mesh, shape)


# ================================================================ MLMC train


def build_mlmc_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                          mlmc_cfg, level: int,
                          *, aggregator: str = "cwmed", attack: str = "none",
                          delta: float = 0.25, opt: Optional[Optimizer] = None,
                          lr: float = 1e-3, agg_backend: str = "auto",
                          dtype=jnp.bfloat16) -> BuiltStep:
    """Algorithm 2 at MLMC level J=`level` in Mode B.

    One round computes three robust-aggregated gradients from nested slices of
    a (B·2^J)-sized per-worker batch — levels 0, J−1, J — then applies
    ``mlmc.mlmc_combine`` guarded by the fail-safe event E_t (Eq. 6), with
    ‖ĝ^J − ĝ^{J−1}‖ a global norm assembled via one scalar psum over the
    worker axes (``core.sharded.make_global_norm``). Beyond-cap levels
    (J > j_max) drop the correction, exactly like the Mode-A drivers.
    """
    from repro.core.mlmc import level_prefix, mlmc_combine
    from repro.core.sharded import make_global_norm

    j = level
    pl = _train_plumbing(cfg, mesh, shape, level_units=2 ** j,
                         aggregator=aggregator, attack=attack, delta=delta,
                         opt=opt, lr=lr, agg_backend=agg_backend, dtype=dtype)
    cfg = pl.cfg
    norm_fn = make_global_norm(pl.plans, pl.waxes)

    def step_local(params, opt_state, batch, maskf, widx):
        with scan_compat.unrolled_scans(_LEGACY_PARTIAL_MANUAL):
            hook = make_param_hook(pl.byz, pl.plans, maskf, widx)

            def agg_grad(b):
                # local (per-worker) batch holds (B/m)·2^j rows; the level-n
                # slice is its nested prefix
                return jax.grad(
                    lambda p: loss_fn(p, b, cfg, param_hook=hook))(params)

            g0 = agg_grad(level_prefix(batch, 1, 2 ** j, axis=0))
            gjm1 = gj = None
            if 1 <= j <= mlmc_cfg.j_max:
                gjm1 = agg_grad(level_prefix(batch, 2 ** (j - 1), 2 ** j,
                                             axis=0))
                gj = agg_grad(level_prefix(batch, 2 ** j, 2 ** j, axis=0))
        g, info = mlmc_combine(g0, gjm1, gj, j, mlmc_cfg, norm_fn=norm_fn)
        updates, opt_state = pl.opt.update(g, opt_state, params)
        params = apply_updates(params, updates)
        ok = jax.lax.pmean(info["failsafe_ok"].astype(jnp.float32), pl.waxes)
        return params, opt_state, (ok, info["corr_norm"])

    return _wrap_train_step(pl, step_local, mesh, (P(), P()),
                            f"mlmc_train[{cfg.arch_id}/{shape.name}/J{j}]")
