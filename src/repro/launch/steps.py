"""Jittable step builders for every (arch × shape) combination.

* ``build_train_step``   — Mode-B DynaBRO robust training step: FSDP params,
  partial-manual shard_map (manual over worker axes, auto over 'model'),
  robust-aggregating custom-VJP gathers, simulated Byzantine mask input.
  MLMC level j is expressed as a 2^j× larger per-worker batch (a mini-batch
  gradient of 2^j unit batches IS the level-j gradient — see DESIGN.md §3),
  so the aggregation applies to worker *means* exactly as in Algorithm 2.
* ``build_prefill_step`` — inference prefill (logits + cache).
* ``build_decode_step``  — one token against a seq_len KV cache.

Each returns (jitted_fn, example_inputs) where example_inputs are
ShapeDtypeStructs with NamedShardings — ready for ``.lower().compile()``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.sharded import ShardedByzConfig, make_param_hook
from repro.launch import sharding as shl
from repro.launch.mesh import (
    n_workers, shard_map, worker_axes, worker_iota, worker_spec,
)
from repro.models import loss_fn, decode_step, prefill
from repro.models import scan_compat

# jax <= 0.4.x: model scans inside the Mode B partial-manual region must
# unroll, including custom-VJP backward scans traced during the grad sweep —
# hence the flag wraps the whole local step, not just forward (DESIGN.md §3).
from repro.compat import LEGACY_PARTIAL_MANUAL as _LEGACY_PARTIAL_MANUAL
from repro.optim.optimizers import Optimizer, apply_updates, sgd


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _strip_model(spec_tree):
    """shard_map in_specs may only mention manual (worker) axes."""
    def strip(s):
        return P(*[None if e == "model" else e for e in s])
    return jax.tree.map(strip, spec_tree, is_leaf=lambda x: isinstance(x, P))


@dataclasses.dataclass
class BuiltStep:
    fn: Any  # jitted callable
    inputs: Tuple  # ShapeDtypeStruct pytrees (positional)
    name: str


def _perf_cfg(cfg: ModelConfig, mesh: Mesh) -> ModelConfig:
    """Per-mesh perf knobs (§Perf). Env overrides allow A/B dry-runs:
    REPRO_ATTN_IMPL=chunked REPRO_MOE_GROUP=0 reproduces the baseline."""
    ms = mesh.shape["model"]
    impl = os.environ.get("REPRO_ATTN_IMPL", cfg.attn_impl)
    seq_shard = ""
    if impl == "flash" and not (cfg.n_heads % ms == 0 and cfg.n_kv_heads % ms == 0):
        # heads don't divide the model axis: shard the q-sequence dim instead
        seq_shard = os.environ.get("REPRO_ATTN_SEQ_SHARD", "model")
    tg = int(os.environ.get("REPRO_MOE_GROUP", str(cfg.moe_token_group)))
    es = ""
    if cfg.is_moe and cfg.n_experts % ms == 0 and impl == "flash":
        es = os.environ.get("REPRO_MOE_EXPERT_SHARD", "model")
    return dataclasses.replace(cfg, attn_impl=impl, attn_seq_shard=seq_shard,
                               moe_token_group=tg, moe_expert_shard=es)


# ================================================================ train


def build_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                     *, aggregator: str = "cwmed", attack: str = "none",
                     level: int = 0, lr: float = 1e-3, delta: float = 0.25,
                     opt: Optional[Optimizer] = None, agg_backend: str = "auto",
                     dtype=jnp.bfloat16) -> BuiltStep:
    cfg = _perf_cfg(cfg, mesh)
    waxes = worker_axes(mesh)
    m = n_workers(mesh)
    byz = ShardedByzConfig(axis_names=waxes, m=m, aggregator=aggregator,
                           delta=delta, attack=attack, backend=agg_backend)
    specs, plans = shl.plan_params(cfg, mesh, fsdp=True, dtype=dtype)
    opt = opt or sgd(lr)

    B = shape.global_batch * (2 ** level)
    S = shape.seq_len
    wspec = worker_spec(waxes)

    def step_local(params, opt_state, batch, maskf, widx):
        with scan_compat.unrolled_scans(_LEGACY_PARTIAL_MANUAL):
            hook = make_param_hook(byz, plans, maskf, widx)
            loss, g = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg, param_hook=hook))(params)
        updates, opt_state = opt.update(g, opt_state, params)
        params = apply_updates(params, updates)
        loss = jax.lax.pmean(loss, waxes)
        return params, opt_state, loss

    pspecs_manual = _strip_model(specs)
    batch_spec = {"tokens": P(wspec, None), "labels": P(wspec, None)}
    extra_spec = {}
    if cfg.family == "audio":
        extra_spec["frames"] = P(wspec, None, None)
    if cfg.family == "vlm":
        extra_spec["patches"] = P(wspec, None, None)
    if extra_spec:
        batch_spec["extra"] = extra_spec

    opt_state_shapes = jax.eval_shape(
        lambda: opt.init(shl.abstract_params(cfg, dtype)))
    opt_specs = _opt_specs(opt_state_shapes, specs)

    smapped = shard_map(
        step_local, mesh=mesh,
        in_specs=(pspecs_manual, _strip_model(opt_specs), batch_spec, P(None),
                  P(wspec)),
        out_specs=(pspecs_manual, _strip_model(opt_specs), P()),
        axis_names=set(waxes), check_vma=False)

    def stepped(params, opt_state, batch, maskf):
        # worker-index iota: sharding over the worker axes hands each device
        # its own flattened index as data (see core.sharded.make_param_hook)
        return smapped(params, opt_state, batch, maskf, worker_iota(m))

    jitted = jax.jit(
        stepped,
        in_shardings=(shl.named(mesh, specs), shl.named(mesh, opt_specs),
                      shl.named(mesh, batch_spec), NamedSharding(mesh, P(None))),
        out_shardings=(shl.named(mesh, specs), shl.named(mesh, opt_specs), None),
        donate_argnums=(0, 1))

    params_in = _sds_tree(shl.abstract_params(cfg, dtype), specs, mesh)
    opt_in = _sds_tree(opt_state_shapes, opt_specs, mesh)
    batch = {"tokens": _sds((B, S), jnp.int32, mesh, batch_spec["tokens"]),
             "labels": _sds((B, S), jnp.int32, mesh, batch_spec["labels"])}
    if cfg.family == "audio":
        batch["extra"] = {"frames": _sds((B, cfg.encoder_seq, cfg.d_model), dtype,
                                         mesh, extra_spec["frames"])}
    if cfg.family == "vlm":
        batch["extra"] = {"patches": _sds((B, cfg.n_image_tokens, cfg.d_model), dtype,
                                          mesh, extra_spec["patches"])}
    maskf = _sds((m,), jnp.float32, mesh, P(None))
    return BuiltStep(jitted, (params_in, opt_in, batch, maskf),
                     f"train[{cfg.arch_id}/{shape.name}/l{level}]")


def _opt_specs(opt_state_shapes, param_specs):
    """Optimizer-state specs: mirror the param specs for param-shaped state
    (momentum/adam), replicate scalars, empty for stateless SGD."""
    state = opt_state_shapes
    if isinstance(state, tuple) and not state:  # sgd
        return ()
    if isinstance(state, dict) and set(state) == {"m", "v", "t"}:  # adam
        return {"m": param_specs, "v": param_specs, "t": P()}
    pstruct = jax.tree_util.tree_structure(param_specs,
                                           is_leaf=lambda x: isinstance(x, P))
    if jax.tree_util.tree_structure(state) == jax.tree_util.tree_structure(
            jax.tree.map(lambda _: 0, state)):
        pass
    try:
        if jax.tree_util.tree_structure(state) == pstruct:  # momentum
            return param_specs
    except Exception:
        pass
    return jax.tree.map(lambda _: P(), state)  # adagrad-norm scalar etc.


def _sds_tree(shapes, specs, mesh):
    flat_sh, treedef = jax.tree_util.tree_flatten(shapes)
    flat_sp = treedef.flatten_up_to(specs)
    return jax.tree_util.tree_unflatten(
        treedef, [_sds(a.shape, a.dtype, mesh, s) for a, s in zip(flat_sh, flat_sp)])


# ================================================================ inference


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                       dtype=jnp.bfloat16) -> BuiltStep:
    cfg = _perf_cfg(cfg, mesh)
    if shape.global_batch % mesh.shape["data"] == 0:
        cfg = dataclasses.replace(cfg, attn_batch_shard="data")
    specs, _ = shl.plan_params(cfg, mesh, fsdp=_infer_fsdp(cfg, mesh), dtype=dtype)
    B, S = shape.global_batch, shape.seq_len
    bspec = shl.batch_specs(cfg, mesh, B, "prefill")

    def fn(params, tokens, extra):
        return prefill(params, tokens, cfg, extra=extra)

    jitted = jax.jit(fn, in_shardings=(shl.named(mesh, specs),
                                       NamedSharding(mesh, bspec["tokens"]),
                                       shl.named(mesh, bspec.get("extra", {}))),
                     out_shardings=None)
    params_in = _sds_tree(shl.abstract_params(cfg, dtype), specs, mesh)
    tokens = _sds((B, S), jnp.int32, mesh, bspec["tokens"])
    extra = {}
    if cfg.family == "audio":
        extra = {"frames": _sds((B, cfg.encoder_seq, cfg.d_model), dtype, mesh,
                                bspec["extra"]["frames"])}
    if cfg.family == "vlm":
        extra = {"patches": _sds((B, cfg.n_image_tokens, cfg.d_model), dtype, mesh,
                                 bspec["extra"]["patches"])}
    return BuiltStep(jitted, (params_in, tokens, extra),
                     f"prefill[{cfg.arch_id}/{shape.name}]")


def build_decode_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                      dtype=jnp.bfloat16) -> BuiltStep:
    cfg = _perf_cfg(cfg.for_shape(shape), mesh)
    specs, _ = shl.plan_params(cfg, mesh, fsdp=_infer_fsdp(cfg, mesh), dtype=dtype)
    B, S = shape.global_batch, shape.seq_len
    cache_shapes, cache_specs = shl.cache_spec_tree(cfg, mesh, B, S)
    tok_spec = P("data") if B % mesh.shape["data"] == 0 else P(None)

    def fn(params, cache, token, pos):
        return decode_step(params, cache, token, pos, cfg)

    jitted = jax.jit(fn, in_shardings=(shl.named(mesh, specs),
                                       shl.named(mesh, cache_specs),
                                       NamedSharding(mesh, tok_spec),
                                       NamedSharding(mesh, P())),
                     out_shardings=None,
                     donate_argnums=(1,))
    params_in = _sds_tree(shl.abstract_params(cfg, dtype), specs, mesh)
    cache_in = _sds_tree(cache_shapes, cache_specs, mesh)
    token = _sds((B,), jnp.int32, mesh, tok_spec)
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    return BuiltStep(jitted, (params_in, cache_in, token, pos),
                     f"decode[{cfg.arch_id}/{shape.name}]")


def _infer_fsdp(cfg: ModelConfig, mesh: Mesh) -> bool:
    """Inference: FSDP the weights too once model-parallel alone would not fit
    comfortably (~> 4 GB/chip of the 16 GB v5e HBM)."""
    return cfg.param_count() * 2 / mesh.shape["model"] > 4e9


def build_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, **kw) -> BuiltStep:
    cfg = cfg.for_shape(shape)
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape)
    return build_decode_step(cfg, mesh, shape)


# ================================================================ MLMC train


def build_mlmc_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                          mlmc_cfg, level: int,
                          *, aggregator: str = "cwmed", attack: str = "none",
                          delta: float = 0.25, opt: Optional[Optimizer] = None,
                          lr: float = 1e-3, agg_backend: str = "auto",
                          dtype=jnp.bfloat16) -> BuiltStep:
    """Algorithm 2 at MLMC level J=`level` in Mode B.

    One round computes three robust-aggregated gradients from nested slices of
    a (B·2^J)-sized per-worker batch — levels 0, J−1, J — then applies the
    MLMC combine guarded by the fail-safe event E_t (Eq. 6). ‖ĝ^J − ĝ^{J−1}‖
    is a global norm assembled with one scalar psum over the worker axes.
    """
    from repro.core.mlmc import level_prefix
    from repro.core.sharded import tree_sq_norm

    waxes = worker_axes(mesh)
    m = n_workers(mesh)
    byz = ShardedByzConfig(axis_names=waxes, m=m, aggregator=aggregator,
                           delta=delta, attack=attack, backend=agg_backend)
    specs, plans = shl.plan_params(cfg, mesh, fsdp=True, dtype=dtype)
    plans_full = {k: v for k, v in plans["top"].items()}
    plans_full["blocks"] = plans["blocks"]
    opt = opt or sgd(lr)
    j = level
    B = shape.global_batch
    S = shape.seq_len
    wspec = worker_spec(waxes)

    def _slice_batch(batch, n_units):
        # local (per-worker) batch holds (B/m)·2^j rows; level-n slice = prefix
        return level_prefix(batch, n_units, 2 ** j, axis=0)

    def step_local(params, opt_state, batch, maskf, widx):
        with scan_compat.unrolled_scans(_LEGACY_PARTIAL_MANUAL):
            hook = make_param_hook(byz, plans, maskf, widx)

            def agg_grad(b):
                return jax.grad(lambda p: loss_fn(p, b, cfg, param_hook=hook))(params)

            g0 = agg_grad(_slice_batch(batch, 1))
            if j >= 1:
                gjm1 = agg_grad(_slice_batch(batch, 2 ** (j - 1)))
                gj = agg_grad(_slice_batch(batch, 2 ** j))
        if j >= 1:
            diff = jax.tree.map(lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                                gj, gjm1)
            dn = jnp.sqrt(tree_sq_norm(diff, plans_full, waxes))
            ok = dn <= mlmc_cfg.threshold(j)
            scale = jnp.where(ok, 2.0 ** j, 0.0)
            g = jax.tree.map(lambda a, d: (a.astype(jnp.float32) + scale * d).astype(a.dtype),
                             g0, diff)
        else:
            g, ok, dn = g0, jnp.array(True), jnp.zeros(())
        updates, opt_state = opt.update(g, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, (jax.lax.pmean(ok.astype(jnp.float32), waxes), dn)

    pspecs_manual = _strip_model(specs)
    batch_spec = {"tokens": P(wspec, None), "labels": P(wspec, None)}
    opt_state_shapes = jax.eval_shape(lambda: opt.init(shl.abstract_params(cfg, dtype)))
    opt_specs = _opt_specs(opt_state_shapes, specs)
    smapped = shard_map(
        step_local, mesh=mesh,
        in_specs=(pspecs_manual, _strip_model(opt_specs), batch_spec, P(None),
                  P(wspec)),
        out_specs=(pspecs_manual, _strip_model(opt_specs), (P(), P())),
        axis_names=set(waxes), check_vma=False)

    def stepped(params, opt_state, batch, maskf):
        return smapped(params, opt_state, batch, maskf, worker_iota(m))

    jitted = jax.jit(
        stepped,
        in_shardings=(shl.named(mesh, specs), shl.named(mesh, opt_specs),
                      shl.named(mesh, batch_spec), NamedSharding(mesh, P(None))),
        out_shardings=(shl.named(mesh, specs), shl.named(mesh, opt_specs), None),
        donate_argnums=(0, 1))
    Bj = B * (2 ** j)
    params_in = _sds_tree(shl.abstract_params(cfg, dtype), specs, mesh)
    opt_in = _sds_tree(opt_state_shapes, opt_specs, mesh)
    batch = {"tokens": _sds((Bj, S), jnp.int32, mesh, batch_spec["tokens"]),
             "labels": _sds((Bj, S), jnp.int32, mesh, batch_spec["labels"])}
    maskf = _sds((m,), jnp.float32, mesh, P(None))
    return BuiltStep(jitted, (params_in, opt_in, batch, maskf),
                     f"mlmc_train[{cfg.arch_id}/{shape.name}/J{j}]")
