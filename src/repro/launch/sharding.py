"""Path-based sharding rules: model-parallel axis per parameter, FSDP axis
over the worker (data / pod×data) axes, KV-cache and activation specs.

The same deterministic rule feeds (a) the jit ``in_shardings`` and (b) the
Mode-B robust-gather hook, so the custom VJP always all-gathers exactly the
axis the spec sharded.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.sharded import fsdp_axis_for
from repro.models import init_params

# --------------------------------------------------------- model-axis rule

# leaf name -> preferred model-sharded dim (checked for divisibility)
_MODEL_AXIS = {
    "wq": 1, "wk": 1, "wv": 1, "wo": 0,
    "bq": 0, "bk": 0, "bv": 0,
    "w1": 1, "w3": 1, "w2": 0,
    "we1": 2, "we3": 2, "we2": 1,
    "in_proj": 1, "out_proj": 0, "x_proj": 0, "dt_proj": 1,
    "conv_w": 1, "conv_b": 0, "A_log": 0, "D": 0, "dt_bias": 0,
    "wg": 1, "wr": 1,
    "embed": 0, "unembed": 1, "dec_pos": 1,
}


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for e in path:
        if hasattr(e, "key"):
            out.append(str(e.key))
        elif hasattr(e, "name"):
            out.append(str(e.name))
    return tuple(out)


def model_axis_rule(path_names: Tuple[str, ...], shape, model_size: int) -> Optional[int]:
    name = path_names[-1] if path_names else ""
    ax = _MODEL_AXIS.get(name)
    if name == "wv" and "mlp" in path_names:  # rwkv channel-mix wv: (F, D)
        ax = 0
    if name in ("we1", "we2", "we3") and shape and shape[0] % model_size == 0:
        ax = 0  # expert parallelism when E divides the model axis (§Perf it.2)
    if ax is None or ax >= len(shape):
        return None
    if shape[ax] % model_size != 0:
        return None
    if functools.reduce(lambda a, b: a * b, shape, 1) < (1 << 14):
        return None
    return ax


# --------------------------------------------------------- parameter plans


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_params, cfg, dtype=dtype), jax.random.PRNGKey(0))


def plan_params(cfg: ModelConfig, mesh: Mesh, *, fsdp: bool, dtype=jnp.bfloat16):
    """Returns (specs, plans):
      specs — PartitionSpec tree matching the full (stacked) param tree;
      plans — {'top': int-tree, 'blocks': int-tree over a group slice},
              leaf = FSDP gather axis in the local view, -1 = replicated.
    """
    shapes = abstract_params(cfg, dtype)
    model_size = mesh.shape["model"]
    waxes = tuple(a for a in mesh.axis_names if a != "model")
    m = 1
    for a in waxes:
        m *= mesh.shape[a]

    def entry(path, leaf, stacked: bool):
        names = _path_names(path)
        shape = leaf.shape[1:] if stacked else leaf.shape
        ma = model_axis_rule(names, shape, model_size)
        fa = fsdp_axis_for(shape, m, ma) if fsdp else None
        spec = [None] * len(shape)
        if ma is not None:
            spec[ma] = "model"
        if fa is not None:
            spec[fa] = waxes if len(waxes) > 1 else waxes[0]
        if stacked:
            spec = [None] + spec
        return P(*spec), (-1 if fa is None else fa)

    top_shapes = {k: v for k, v in shapes.items() if k != "blocks"}
    top_specs = {}
    top_plan = {}
    flat, treedef = jax.tree_util.tree_flatten_with_path(top_shapes)
    specs_leaves, plan_leaves = [], []
    for path, leaf in flat:
        s, pl = entry(path, leaf, stacked=False)
        specs_leaves.append(s)
        plan_leaves.append(pl)
    top_specs = jax.tree_util.tree_unflatten(treedef, specs_leaves)
    top_plan = jax.tree_util.tree_unflatten(treedef, plan_leaves)

    blk_shapes = shapes["blocks"]
    flatb, treedefb = jax.tree_util.tree_flatten_with_path(blk_shapes)
    bspecs, bplan = [], []
    for path, leaf in flatb:
        s, pl = entry(path, leaf, stacked=True)
        bspecs.append(s)
        bplan.append(pl)
    blk_specs = jax.tree_util.tree_unflatten(treedefb, bspecs)
    blk_plan = jax.tree_util.tree_unflatten(treedefb, bplan)

    specs = {**top_specs, "blocks": blk_specs}
    plans = {"top": top_plan, "blocks": blk_plan}
    return specs, plans


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def strip_model(spec_tree):
    """Drop the 'model' entries of a spec tree — shard_map regions manual
    over the worker axes only may not mention the auto 'model' axis in their
    in/out_specs (the Mode-B partial-manual lowering, DESIGN.md §3)."""
    def strip(s):
        return P(*[None if e == "model" else e for e in s])
    return jax.tree.map(strip, spec_tree, is_leaf=lambda x: isinstance(x, P))


def opt_specs(opt_state_shapes, param_specs):
    """Optimizer-state specs: mirror the param specs for param-shaped state
    (momentum/adam), replicate scalars, empty for stateless SGD."""
    state = opt_state_shapes
    if isinstance(state, tuple) and not state:  # sgd
        return ()
    if isinstance(state, dict) and set(state) == {"m", "v", "t"}:  # adam
        return {"m": param_specs, "v": param_specs, "t": P()}
    pstruct = jax.tree_util.tree_structure(param_specs,
                                           is_leaf=lambda x: isinstance(x, P))
    try:
        if jax.tree_util.tree_structure(state) == pstruct:  # momentum
            return param_specs
    except Exception:
        pass
    return jax.tree.map(lambda _: P(), state)  # adagrad-norm scalar etc.


def sds(shape, dtype, mesh: Mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def sds_tree(shapes, specs, mesh: Mesh):
    """ShapeDtypeStructs with NamedShardings for an abstract tree + specs."""
    flat_sh, treedef = jax.tree_util.tree_flatten(shapes)
    flat_sp = treedef.flatten_up_to(specs)
    return jax.tree_util.tree_unflatten(
        treedef, [sds(a.shape, a.dtype, mesh, s)
                  for a, s in zip(flat_sh, flat_sp)])


def batch_sds(cfg: ModelConfig, mesh: Mesh, global_batch: int, seq_len: int,
              *, kind: str = "train", dtype=jnp.bfloat16):
    """(specs, example) for the input batch — the ONE builder both Mode-B
    step builders draw their batch specs and example ShapeDtypeStructs from,
    so the family-dependent ``extra`` leaves (audio frames / vlm patches)
    cannot drift between them again (the PR-7 bug: ``build_mlmc_train_step``
    dropped them and could not run the whisper/vision configs)."""
    spec = batch_specs(cfg, mesh, global_batch, kind)
    B = global_batch
    ex = {"tokens": sds((B, seq_len), jnp.int32, mesh, spec["tokens"])}
    if kind == "train":
        ex["labels"] = sds((B, seq_len), jnp.int32, mesh, spec["labels"])
    if "extra" in spec:
        extra = {}
        if cfg.family == "audio":
            extra["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), dtype,
                                  mesh, spec["extra"]["frames"])
        if cfg.family == "vlm":
            extra["patches"] = sds((B, cfg.n_image_tokens, cfg.d_model), dtype,
                                   mesh, spec["extra"]["patches"])
        ex["extra"] = extra
    return spec, ex


# --------------------------------------------------------- data & cache


def batch_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int, kind: str):
    """Specs for the input batch pytree."""
    waxes = tuple(a for a in mesh.axis_names if a != "model")
    m = 1
    for a in waxes:
        m *= mesh.shape[a]
    b_ax = (waxes if len(waxes) > 1 else waxes[0]) if global_batch % m == 0 else None
    tok = P(b_ax, None) if kind != "decode" else P(b_ax)
    spec = {"tokens": tok, "labels": P(b_ax, None)}
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = P(b_ax, None, None)
    if cfg.family == "vlm":
        extra["patches"] = P(b_ax, None, None)
    if kind == "train":
        if extra:
            spec["extra"] = extra
        return spec
    if kind == "prefill":
        return {"tokens": tok, **({"extra": extra} if extra else {})}
    return {"tokens": tok}


def cache_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int):
    """Specs for the decode cache (leaves stacked over n_groups)."""
    model_size = mesh.shape["model"]
    data_ok = global_batch % mesh.shape["data"] == 0

    def leaf_spec(path, leaf):
        names = _path_names(path)
        shape = leaf.shape  # (n_groups, B, ...)
        name = names[-1]
        spec = [None] * len(shape)
        if data_ok and shape[1] % mesh.shape["data"] == 0:
            spec[1] = "data"
        if name in ("k", "v"):  # (g, B, S, KV, hd)
            if shape[3] % model_size == 0:
                spec[3] = "model"
            elif shape[2] % model_size == 0:
                spec[2] = "model"
        elif name == "conv":  # (g, B, k-1, di)
            if shape[3] % model_size == 0:
                spec[3] = "model"
        elif name == "ssm":  # (g, B, di, ds)
            if shape[2] % model_size == 0:
                spec[2] = "model"
        elif name == "state":  # (g, B, H, hd, hd)
            if shape[2] % model_size == 0:
                spec[2] = "model"
        elif name == "prev":  # (g, B, D)
            if shape[2] % model_size == 0:
                spec[2] = "model"
        return P(*spec)

    from repro.models import init_cache

    shapes = jax.eval_shape(lambda: init_cache(cfg, global_batch, 1))
    # note: caller re-evaluates with the true seq_len; here only structure is
    # needed, so build specs from the real abstract tree instead:
    return shapes, leaf_spec


def cache_spec_tree(cfg: ModelConfig, mesh: Mesh, batch: int, seq_len: int):
    from repro.models import init_cache

    shapes = jax.eval_shape(functools.partial(init_cache, cfg, batch, seq_len))
    _, leaf_spec = cache_specs(cfg, mesh, batch)
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = [leaf_spec(path, leaf) for path, leaf in flat]
    return shapes, jax.tree_util.tree_unflatten(treedef, specs)
