"""End-to-end DynaBRO training driver (Mode B).

Runs Algorithm 2 on a real device mesh: per round, sample J ~ Geom(1/2)
host-side, dispatch to the per-level compiled step (lowered lazily, cached),
feed per-worker synthetic LM batches, update the Byzantine mask from the
switching strategy, checkpoint periodically.

On this CPU container, pass ``--devices N`` to spawn N placeholder devices
(the flag is applied before JAX init via re-exec). Example:

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \\
      --devices 8 --mesh 4x2 --steps 50 --reduced --attack sign_flip \\
      --aggregator cwtm --switch periodic --switch-k 10
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _reexec_with_devices(n: int):
    if os.environ.get("_REPRO_DEVICES_SET") == str(n):
        return
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count={n}").strip()
    os.environ["_REPRO_DEVICES_SET"] = str(n)
    os.execv(sys.executable, [sys.executable] + sys.argv)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="", help="e.g. 4x2 (data x model)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--optimizer", default="sgd",
                    choices=["sgd", "momentum", "adam", "adagrad_norm"])
    ap.add_argument("--aggregator", default="cwmed")
    ap.add_argument("--attack", default="none")
    ap.add_argument("--delta", type=float, default=0.25)
    ap.add_argument("--switch", default="static",
                    choices=["static", "periodic", "bernoulli", "momentum_tailored"])
    ap.add_argument("--switch-k", type=int, default=10)
    ap.add_argument("--n-byz", type=int, default=1)
    ap.add_argument("--mlmc", action="store_true", help="full MLMC levels")
    ap.add_argument("--V", type=float, default=8.0)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        _reexec_with_devices(args.devices)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import save_checkpoint
    from repro.configs import get_config, reduced
    from repro.configs.base import ShapeConfig
    from repro.core.mlmc import MLMCConfig, sample_level
    from repro.core.switching import get_switcher
    from repro.data import SyntheticLMData
    from repro.launch.mesh import set_mesh
    from repro.launch.steps import build_mlmc_train_step, build_train_step
    from repro.models import init_params
    from repro.optim.optimizers import get_optimizer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = jax.make_mesh(dims, axes)
    else:
        n = len(jax.devices())
        mesh = jax.make_mesh((n, 1), ("data", "model"))
    m = 1
    for a in mesh.axis_names:
        if a != "model":
            m *= mesh.shape[a]
    print(f"mesh={dict(mesh.shape)} workers(m)={m} arch={cfg.arch_id} "
          f"params={cfg.param_count()/1e6:.1f}M")

    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")
    opt = get_optimizer(args.optimizer, args.lr)
    mlmc_cfg = MLMCConfig(T=args.steps, m=m, V=args.V, option=1, kappa=1.0,
                          j_cap=3)
    sw_kw = {"static": {"n_byz": args.n_byz},
             "periodic": {"n_byz": args.n_byz, "K": args.switch_k},
             "bernoulli": {"p": 0.02, "D": args.switch_k, "delta_max": 0.45},
             "momentum_tailored": {"alpha": 0.1}}[args.switch]
    switcher = get_switcher(args.switch, m, seed=args.seed, **sw_kw)
    data = SyntheticLMData(cfg.vocab_size, args.seq_len, args.global_batch,
                           seed=args.seed)

    dtype = jnp.float32 if args.reduced else jnp.bfloat16
    steps_cache = {}

    def get_step(j):
        if j not in steps_cache:
            if j == 0 or not args.mlmc:
                steps_cache[j] = build_train_step(
                    cfg, mesh, shape, aggregator=args.aggregator,
                    attack=args.attack, lr=args.lr, delta=args.delta, opt=opt,
                    dtype=dtype)
            else:
                steps_cache[j] = build_mlmc_train_step(
                    cfg, mesh, shape, mlmc_cfg, j, aggregator=args.aggregator,
                    attack=args.attack, delta=args.delta, opt=opt, dtype=dtype)
        return steps_cache[j]

    def place(tree, like):
        return jax.tree.map(lambda x, s: jax.device_put(x, s.sharding), tree, like)

    params = init_params(cfg, jax.random.PRNGKey(args.seed), dtype=dtype)
    opt_state = opt.init(params)
    rng = np.random.default_rng(args.seed)
    t_start = time.time()
    placed = False
    with set_mesh(mesh):
        for t in range(args.steps):
            j = sample_level(rng, mlmc_cfg.j_max) if args.mlmc else 0
            j = min(j, mlmc_cfg.j_max)
            step = get_step(j)
            if not placed:  # shard initial state per the step's plan
                params = place(params, step.inputs[0])
                opt_state = place(opt_state, step.inputs[1])
                placed = True
            mult = 2 ** j if (args.mlmc and j > 0) else 1
            batch = data.batch(t, args.global_batch * mult)
            batch = place(batch, step.inputs[2])
            maskf = place(jnp.asarray(switcher.mask(t), jnp.float32),
                          step.inputs[3])
            params, opt_state, out = step.fn(params, opt_state, batch, maskf)
            if args.mlmc and j > 0:
                ok, dn = out
                msg = f"J={j} failsafe_ok={float(ok):.0f} |ĝJ-ĝJ-1|={float(dn):.3f}"
            else:
                msg = f"loss={float(out):.4f}"
            if t % max(1, args.steps // 20) == 0 or t == args.steps - 1:
                print(f"step {t:5d} byz={int(maskf.sum())}/{m} {msg} "
                      f"({time.time()-t_start:.1f}s)")
            if args.ckpt_every and (t + 1) % args.ckpt_every == 0:
                save_checkpoint(os.path.join(args.ckpt_dir,
                                             f"{cfg.arch_id}_step{t+1}"),
                                params, step=t + 1)
    print("done in", round(time.time() - t_start, 1), "s")


if __name__ == "__main__":
    main()
