"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

MUST run as its own process: the first two lines pin 512 placeholder devices
before any other import (JAX locks the device count on first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all        # orchestrates subprocesses

Each run writes experiments/dryrun/<arch>__<shape>__<mesh>.json with
cost_analysis, memory_analysis and the parsed per-device collective bytes —
the inputs to the §Roofline report (benchmarks/roofline.py).
"""
import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh, set_mesh  # noqa: E402
from repro.launch.steps import build_step  # noqa: E402

OUT_DIR = os.environ.get("REPRO_DRYRUN_OUT") or os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims, in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_RE2 = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str) -> int:
    m = _GROUP_RE.search(line)
    if m:  # iota format [n_groups, group_size]<=[...]
        return int(m.group(2))
    m = _GROUP_RE2.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


_COLL_RE = re.compile(
    r"=\s+(\(?[\w\[\],{}\s/*+=]*?\)?)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(-start)?\(")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)(?:\.v\d+)? \(.*\) -> .* \{\s*$")
_WHILE_RE = re.compile(r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_LIMIT_RE = re.compile(r"s32\[\] constant\((\d+)\)")


def _split_computations(hlo: str) -> dict:
    """name -> list of body lines."""
    comps, cur, name = {}, None, None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m:
            name = m.group(1)
            cur = []
            comps[name] = cur
            continue
        if line.startswith("}"):
            name, cur = None, None
            continue
        if cur is not None:
            cur.append(line.strip())
    return comps


def parse_collectives(hlo: str) -> dict:
    """Per-device collective bytes by kind, weighted by while-loop (lax.scan)
    trip counts (parsed from each loop condition's comparison constant).
    Roofline convention: ring algorithms move size*(n-1)/n per device,
    all-reduce moves 2x that."""
    comps = _split_computations(hlo)
    # trip counts: condition computation -> limit constant (max s32 constant)
    cond_limit = {}
    for name, lines in comps.items():
        consts = [int(x) for l in lines for x in _LIMIT_RE.findall(l)]
        if consts:
            cond_limit[name] = max(consts)

    def line_bytes(s):
        m = _COLL_RE.search(s)
        if not m:
            return None
        shape_txt, op = m.group(1), m.group(2)
        if m.group(3) is None and (op + "-done(") in s:
            return None
        nbytes = _shape_bytes(shape_txt)
        n = _group_size(s)
        frac = (n - 1) / max(n, 1)
        if op == "all-reduce":
            moved = 2.0 * nbytes * frac
        elif op == "all-gather":
            moved = nbytes * frac  # output-sized
        elif op == "reduce-scatter":
            moved = float(nbytes)  # input-sized reduced tensor moves (n-1)/n*in
        elif op == "all-to-all":
            moved = nbytes * frac
        else:
            moved = float(nbytes)
        return op, moved

    import functools as _ft

    @_ft.lru_cache(maxsize=None)
    def comp_totals(name):
        out = {k: 0.0 for k in _COLLECTIVES}
        counts = {k: 0.0 for k in _COLLECTIVES}
        for s in comps.get(name, ()):
            lb = line_bytes(s)
            if lb and "-done(" not in s.split("(")[0]:
                op, moved = lb
                out[op] += moved
                counts[op] += 1
            w = _WHILE_RE.search(s)
            if w:
                cond, body = w.group(1), w.group(2)
                trip = cond_limit.get(cond, 1)
                sub_out, sub_counts = comp_totals(body)
                for k in _COLLECTIVES:
                    out[k] += trip * sub_out[k]
                    counts[k] += trip * sub_counts[k]
        return out, counts

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY %?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: flat sum over all computations
        entry_totals = [comp_totals(n) for n in comps]
        out = {k: sum(t[0][k] for t in entry_totals) for k in _COLLECTIVES}
        counts = {k: sum(t[1][k] for t in entry_totals) for k in _COLLECTIVES}
    else:
        out, counts = comp_totals(entry)
    return {"bytes": out, "counts": counts, "total_bytes": sum(out.values())}


def run_one(arch: str, shape_name: str, multi_pod: bool,
            aggregator: str = "cwmed", attack: str = "none",
            level: int = 0, out_dir: str = OUT_DIR, tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not cfg.supports_shape(shape):
        rec = {"arch": arch, "shape": shape_name, "skipped": True,
               "reason": "unsupported (see DESIGN.md §Arch-applicability)"}
        _write(rec, arch, shape_name, multi_pod, out_dir, tag)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    built = build_step(cfg, mesh, shape, aggregator=aggregator, attack=attack,
                       level=level) if shape.kind == "train" else \
        build_step(cfg, mesh, shape)
    with set_mesh(mesh):
        lowered = built.fn.lower(*built.inputs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    mem = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem[f] = getattr(ma, f, None)
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    weighted = parse_weighted_costs(hlo)
    _save_hlo(hlo, arch, shape_name, multi_pod, out_dir, tag)
    rec = {
        "arch": arch, "shape": shape_name,
        "weighted": weighted,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "level": level,
        "aggregator": aggregator if shape.kind == "train" else None,
        "flops": ca.get("flops"), "bytes_accessed": ca.get("bytes accessed"),
        "transcendentals": ca.get("transcendentals"),
        "memory": mem, "collectives": coll,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "hlo_bytes": len(hlo),
        "step_name": built.name,
    }
    _write(rec, arch, shape_name, multi_pod, out_dir, tag)
    return rec


def _save_hlo(hlo: str, arch, shape_name, multi_pod, out_dir, tag=""):
    import gzip
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}{suffix}.hlo.gz")
    with gzip.open(path, "wt") as f:
        f.write(hlo)


def _write(rec, arch, shape_name, multi_pod, out_dir, tag=""):
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] wrote {path}")


def orchestrate(jobs, parallel: int = 4, extra_args=()):
    """Run each (arch, shape, multi_pod) in its own subprocess."""
    procs = []
    results = {}

    def launch(job):
        arch, shape, mp = job
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape] + (["--multi-pod"] if mp else []) + list(extra_args)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "..")
        return job, subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                                     stderr=subprocess.STDOUT, text=True)

    queue = list(jobs)
    running = []
    while queue or running:
        while queue and len(running) < parallel:
            running.append(launch(queue.pop(0)))
        done = []
        for i, (job, p) in enumerate(running):
            if p.poll() is not None:
                out = p.stdout.read()
                ok = p.returncode == 0
                results[job] = ok
                status = "OK" if ok else "FAIL"
                print(f"[{status}] {job}")
                if not ok:
                    print(out[-3000:])
                done.append(i)
        for i in reversed(done):
            running.pop(i)
        time.sleep(1.0)
    n_ok = sum(results.values())
    print(f"\n{n_ok}/{len(results)} dry-runs succeeded")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["all"], default=None)
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="all 40 pairs, both meshes")
    ap.add_argument("--aggregator", default="cwmed")
    ap.add_argument("--attack", default="none")
    ap.add_argument("--level", type=int, default=0)
    ap.add_argument("--parallel", type=int, default=4)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.all:
        jobs = [(a, s, mp) for a in ARCH_IDS for s in SHAPES
                for mp in (False, True)]
        orchestrate(jobs, parallel=args.parallel)
        return
    archs = ARCH_IDS if args.arch in (None, "all") else [args.arch]
    shapes = list(SHAPES) if args.shape in (None, "all") else [args.shape]
    if len(archs) * len(shapes) > 1:
        orchestrate([(a, s, args.multi_pod) for a in archs for s in shapes],
                    parallel=args.parallel)
        return
    rec = run_one(archs[0], shapes[0], args.multi_pod, aggregator=args.aggregator,
                  attack=args.attack, level=args.level, tag=args.tag)
    if not rec.get("skipped"):
        print(json.dumps({k: rec[k] for k in
                          ("flops", "bytes_accessed", "memory", "collectives",
                           "t_compile_s")}, indent=1, default=str))




# ---------------------------------------------------------------- weighted costs

_DOT_RE = re.compile(
    r"%?([\w.\-]+) = (\S+) dot\(%?([\w.\-]+),? %?([\w.\-]+)\), .*?"
    r"lhs_contracting_dims=\{([\d,]*)\}")
_DEF_RE = re.compile(r"^(?:ROOT )?%?([\w.\-]+) = (\(?[\w\[\],{}\s/*]+?\)?) ")


def _dims_of(type_txt: str):
    m = _SHAPE_RE.search(type_txt)
    if not m:
        return None
    return [int(x) for x in m.group(2).split(",") if x]


def parse_weighted_costs(hlo: str) -> dict:
    """Trip-weighted per-device FLOPs (dot ops) and materialized bytes
    (fusion/dot/copy/conv outputs+operands), from the optimized HLO.

    XLA's compiled.cost_analysis() counts each while (lax.scan) body ONCE;
    this analyzer multiplies by the loop trip count parsed from each loop
    condition, giving the true per-step cost for scan-over-layers models.
    """
    comps = _split_computations(hlo)
    cond_limit = {}
    for name, lines in comps.items():
        consts = [int(x) for l in lines for x in _LIMIT_RE.findall(l)]
        if consts:
            cond_limit[name] = max(consts)

    BYTES_OPS = ("fusion(", "dot(", "convolution(", "copy(", "dynamic-slice(",
                 "dynamic-update-slice(", "sort(", "reduce(", "transpose(",
                 "all-gather(", "all-to-all(", "broadcast(", "concatenate(")

    import functools as _ft

    @_ft.lru_cache(maxsize=None)
    def comp_cost(name):
        flops = 0.0
        byts = 0.0
        shapes = {}
        lines = comps.get(name, ())
        for s in lines:
            dm = _DEF_RE.match(s)
            if dm:
                shapes[dm.group(1)] = dm.group(2)
        for s in lines:
            dm = _DEF_RE.match(s)
            out_type = dm.group(2) if dm else ""
            mdot = _DOT_RE.search(s)
            if mdot:
                out_dims = _dims_of(mdot.group(2)) or []
                lhs_type = shapes.get(mdot.group(3).rstrip(","), "")
                lhs_dims = _dims_of(lhs_type)
                cdims = [int(x) for x in mdot.group(5).split(",") if x]
                k = 1
                if lhs_dims:
                    for c in cdims:
                        if c < len(lhs_dims):
                            k *= lhs_dims[c]
                n = 1
                for d in out_dims:
                    n *= d
                flops += 2.0 * n * k
            if any(op in s for op in BYTES_OPS) and " = " in s:
                byts += _shape_bytes(out_type)
                for opn in re.findall(r"%([\w.\-]+)", s.split("(", 1)[1] if "(" in s else ""):
                    if opn in shapes:
                        byts += _shape_bytes(shapes[opn])
            w = _WHILE_RE.search(s)
            if w:
                trip = cond_limit.get(w.group(1), 1)
                f2, b2 = comp_cost(w.group(2))
                flops += trip * f2
                byts += trip * b2
            # fusion calls reference a computation: calls=%fused_x
            fc = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", s)
            if fc and fc.group(1) in comps and "while(" not in s:
                f2, b2 = comp_cost(fc.group(1))
                flops += f2  # fusion bodies contain dots on CPU sometimes
                byts += 0.0  # avoid double-counting buffer traffic
        return flops, byts

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY %?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry and entry in comps:
        f, b = comp_cost(entry)
    else:
        f = b = 0.0
    return {"flops_weighted": f, "bytes_weighted": b}


if __name__ == "__main__":
    main()
