"""Production meshes.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model").

DynaBRO workers = the data-parallel groups: m=16 (single pod) or m=32
(pod × data combined) — each worker is one model-parallel slice of 16 chips.
"""
from __future__ import annotations

import jax


def set_mesh(mesh):
    """Context manager activating `mesh`: ``jax.set_mesh`` where it exists
    (jax >= 0.5), else the Mesh object itself (it is a context manager)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, axis_names, check_vma=False):
    """``jax.shard_map`` compat: new API where available, else the
    jax.experimental version (manual over `axis_names`, auto elsewhere)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(axis_names),
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset(mesh.axis_names) - set(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma, auto=auto)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def worker_axes(mesh) -> tuple:
    """The axes across which DynaBRO workers are laid out."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_workers(mesh) -> int:
    n = 1
    for a in worker_axes(mesh):
        n *= mesh.shape[a]
    return n


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh(shape, axes)


def worker_spec(waxes):
    """PartitionSpec entry for a leading worker axis: the tuple of worker mesh
    axes, collapsed to the bare name when there is only one."""
    return tuple(waxes) if len(waxes) > 1 else waxes[0]


def worker_iota(m: int):
    """The worker-index-as-data iota (DESIGN.md §3): sharded over the worker
    axes, each device's local slice is its own flattened worker index."""
    import jax.numpy as jnp

    return jnp.arange(m, dtype=jnp.float32)


def make_worker_mesh(n_devices: int = 0, axis: str = "workers",
                     model: int = 0):
    """Worker mesh for the sharded compiled driver (DESIGN.md §7, §9).

    ``model=0`` (default) builds the 1-D ``(workers,)`` mesh of the
    fully-manual shard_map path; ``n_devices=0`` uses every device and
    ``n_devices=1`` gives the parity-contract mesh (bitwise-identical to the
    unsharded driver).

    ``model>=1`` builds the 2-axis ``(workers, model)`` mesh of the model-zoo
    GSPMD path: ``n_devices`` (0 = whatever the model axis leaves over)
    counts the *worker*-axis size, and the per-leaf FSDP/model partition
    rules of ``launch.sharding.plan_params`` apply unchanged (the worker
    axis doubles as the FSDP axis, exactly like Mode B's 'data'). A
    ``(1, 1)`` mesh is the parity-contract mesh of this path."""
    devs = jax.devices()
    if model:
        n = n_devices or max(1, len(devs) // model)
        if n * model > len(devs):
            raise ValueError(
                f"requested {n}x{model} devices, have {len(devs)}")
        return jax.make_mesh((n, model), (axis, "model"),
                             devices=devs[: n * model])
    n = n_devices or len(devs)
    if n > len(devs):
        raise ValueError(f"requested {n} devices, have {len(devs)}")
    return jax.make_mesh((n,), (axis,), devices=devs[:n])


def make_lane_mesh(n_lanes: int = 0, n_workers: int = 1,
                   lane_axis: str = "lanes", worker_axis: str = "workers"):
    """2-axis ``(lanes, workers)`` mesh for the sharded vmapped sweep
    (DESIGN.md §12): the sweep's cell lanes are split over ``lane_axis``
    and, with ``n_workers > 1``, each lane's per-worker gradient vmap over
    ``worker_axis`` (the 1-axis driver's worker sharding, nested inside the
    lane split). ``n_lanes=0`` uses whatever the worker axis leaves over;
    a ``(1, 1)`` mesh is this path's parity-contract mesh — the sweep skips
    the shard_map wrap entirely, so it is bitwise-identical to the
    unsharded sweep by construction."""
    devs = jax.devices()
    if n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {n_workers}")
    n = n_lanes or max(1, len(devs) // n_workers)
    if n * n_workers > len(devs):
        raise ValueError(
            f"requested {n}x{n_workers} devices, have {len(devs)}")
    return jax.make_mesh((n, n_workers), (lane_axis, worker_axis),
                         devices=devs[: n * n_workers])
