"""Bounded thread-safe ring buffer — the ingress queue of the aggregation
server (DESIGN.md §10).

Producers (worker clients) ``put`` update messages; a full ring blocks the
producer up to its timeout — that IS the backpressure mechanism, there is no
silent drop path. The single consumer (the server loop) ``get``s them out.
``close()`` wakes every waiter so shutdown never deadlocks on a blocked
producer or consumer.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, Dict, Optional


class RingBuffer:
    """FIFO with a hard capacity. ``put`` returns False instead of enqueuing
    when the ring stays full past the timeout (or the ring is closed) —
    callers count that as a backpressure rejection. Stats are monotonic
    counters plus a high-water mark, all read under the same lock."""

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError(f"ring capacity must be positive, got {capacity}")
        self._capacity = capacity
        self._buf: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self._closed = False
        self._pushed = 0
        self._rejected = 0
        self._high_water = 0

    @property
    def capacity(self) -> int:
        return self._capacity

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    def put(self, item: Any, timeout: Optional[float] = None) -> bool:
        """Enqueue; block while full. False = rejected (timeout while full,
        or ring closed) — the producer-visible backpressure signal."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._not_full:
            while len(self._buf) >= self._capacity and not self._closed:
                wait = (None if deadline is None
                        else deadline - time.monotonic())
                if wait is not None and wait <= 0:
                    self._rejected += 1
                    return False
                self._not_full.wait(wait)
            if self._closed:
                self._rejected += 1
                return False
            self._buf.append(item)
            self._pushed += 1
            self._high_water = max(self._high_water, len(self._buf))
            self._not_empty.notify()
            return True

    def get(self, timeout: Optional[float] = None) -> Optional[Any]:
        """Dequeue; block while empty. None = nothing arrived within the
        timeout, or the ring is closed and drained."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._not_empty:
            while not self._buf:
                if self._closed:
                    return None
                wait = (None if deadline is None
                        else deadline - time.monotonic())
                if wait is not None and wait <= 0:
                    return None
                self._not_empty.wait(wait)
            item = self._buf.popleft()
            self._not_full.notify()
            return item

    def close(self) -> None:
        """Stop accepting puts and wake every blocked producer/consumer;
        already-queued items remain drainable via ``get``."""
        with self._lock:
            self._closed = True
            self._not_full.notify_all()
            self._not_empty.notify_all()

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "ring_depth": len(self._buf),
                "ring_capacity": self._capacity,
                "ring_pushed": self._pushed,
                "ring_rejected": self._rejected,
                "ring_high_water": self._high_water,
            }
