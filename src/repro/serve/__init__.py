"""``repro.serve`` — the continuously-running robust-aggregation service
(DESIGN.md §10).

Simulated worker clients push ``(worker_id, round, update)`` messages into a
bounded ring buffer; the server drains them through the jitted per-round
session step (MLMC estimation + fused aggregation + optimizer update),
checkpoints the scan carry on an interval, and exposes health / throughput /
staleness metrics over a lightweight HTTP endpoint plus a structured JSONL
metrics log. Robustness is first-class: a worker that misses its round
deadline is masked as dynamically Byzantine for that round (the switcher
mask path), a full ring applies backpressure to submitters, and shutdown is
a graceful drain with a bitwise-resumable final checkpoint.
"""
from repro.serve.client import SimulatedWorkers, worker_payloads
from repro.serve.health import HealthEndpoint
from repro.serve.metrics import MetricsLog, ServeMetrics
from repro.serve.ring import RingBuffer
from repro.serve.server import AggregationServer, ServeConfig, Update

__all__ = [
    "AggregationServer", "ServeConfig", "Update", "RingBuffer",
    "ServeMetrics", "MetricsLog", "HealthEndpoint",
    "SimulatedWorkers", "worker_payloads",
]
