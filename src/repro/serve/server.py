"""The aggregation server (DESIGN.md §10): drain worker updates from the
ring buffer through the session's jitted per-round step.

One consumer thread owns the round loop: it pops ``(worker_id, round,
payload)`` messages off the ring, files them into a per-round pending table,
and when round ``r`` is ready — every worker present, or the round deadline
passed with at least ``min_workers`` present — assembles the (m, n_max, ...)
batch, ORs timed-out workers into the round's Byzantine mask (a straggler is
just a dynamically-Byzantine worker: the aggregator's robustness bound
already covers it, so no special recovery path exists), and advances the
scan carry with ``Session.step``. Because ``step`` drives the same compiled
segment the offline scan driver uses, a fully-delivered stream is
bitwise-identical to ``run_dynabro_scan`` on the same schedule — locked by
tests/test_serve.py.

Flow control is two-layer: ``submit`` blocks messages more than
``lookahead_rounds`` ahead of the server's current round (so a fast worker
cannot flood memory with far-future rounds), and the bounded ring blocks
once full. The carry checkpoints every ``checkpoint_every`` rounds via the
``checkpoint/`` machinery; a graceful drain writes a final checkpoint at an
exact round boundary, so a restarted server resumes bitwise.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.session import RoundInputs, Session
from repro.checkpoint import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.core.mlmc import round_cost
from repro.core.robust_train import RoundLog
from repro.serve.health import HealthEndpoint
from repro.serve.metrics import MetricsLog, ServeMetrics
from repro.serve.ring import RingBuffer


class Update(NamedTuple):
    """One worker->server message. ``payload`` is the worker's padded
    per-round batch slice (tree with leading (n_max,) unit axis) — the Mode-A
    simulation analog of a gradient update: gradients are computed inside the
    server's worker-vmapped step so the parity contract stays bitwise (a
    per-worker out-of-graph gradient could differ in fusion order)."""

    worker_id: int
    round: int
    payload: Any
    sent_at: float  # time.monotonic() at submit, for staleness metrics


@dataclasses.dataclass
class ServeConfig:
    """Server knobs. ``round_timeout_s=None`` waits forever for every worker
    (no straggler masking); with a timeout, a round is processed once at
    least ``min_workers`` arrived and the deadline (measured from the round's
    first arrival) passed. ``health_port`` None disables the HTTP endpoint;
    0 binds an ephemeral port (see ``AggregationServer.health``)."""

    capacity: int = 1024
    round_timeout_s: Optional[float] = None
    min_workers: int = 1
    lookahead_rounds: int = 8
    checkpoint_every: int = 0
    checkpoint_dir: Optional[str] = None
    metrics_log: Optional[str] = None
    health_port: Optional[int] = None
    poll_s: float = 0.02


class AggregationServer:
    """See the module docstring. Lifecycle: ``start()`` → clients
    ``submit(...)`` → ``stop(drain=True)`` (graceful) or ``stop(drain=False)``
    (kill: in-flight round finishes, nothing past the last checkpoint
    survives) → ``close()``. ``AggregationServer.resume(...)`` rebuilds from
    the newest checkpoint in ``cfg.checkpoint_dir``."""

    def __init__(self, session: Session, T: int,
                 cfg: Optional[ServeConfig] = None, *,
                 start_round: int = 0, carry=None):
        if session.m is None:
            raise ValueError("serve needs the session's worker count; build "
                             "it with switcher= or m=")
        self.session = session
        self.T = T
        self.cfg = cfg or ServeConfig()
        self.m = session.m
        self.sched = session.schedule(T)
        self.start_round = start_round
        self.carry = carry if carry is not None else session.init_carry()
        self.ring = RingBuffer(self.cfg.capacity)
        self.metrics = ServeMetrics()
        self.logs: List[RoundLog] = []
        self.error: Optional[BaseException] = None
        self.health: Optional[HealthEndpoint] = None
        self._log = MetricsLog(self.cfg.metrics_log)
        self._round = start_round
        self._pending: Dict[int, Dict[int, Any]] = {}
        self._deadline: Optional[float] = None
        self._last_ckpt = start_round
        self._admit = threading.Condition()
        self._stop = threading.Event()
        self._draining = threading.Event()
        self._done = threading.Event()
        self._thread: Optional[threading.Thread] = None

    @classmethod
    def resume(cls, session: Session, T: int,
               cfg: ServeConfig) -> "AggregationServer":
        """Rebuild from the newest complete checkpoint in
        ``cfg.checkpoint_dir`` (fresh server at round 0 if there is none).
        The restored carry re-enters the same compiled step, so the resumed
        stream continues bitwise from the checkpointed round boundary."""
        if not cfg.checkpoint_dir:
            raise ValueError("resume needs cfg.checkpoint_dir")
        found = latest_checkpoint(cfg.checkpoint_dir, prefix="carry_")
        if found is None:
            return cls(session, T, cfg)
        path, step = found
        carry = load_checkpoint(path, session.init_carry())
        return cls(session, T, cfg, start_round=step, carry=carry)

    # ------------------------------------------------------------ ingress

    def submit(self, worker_id: int, round: int, payload: Any,
               timeout: Optional[float] = None) -> bool:
        """Client-side entrypoint (thread-safe). Blocks under backpressure —
        the round is beyond the lookahead window, or the ring is full — up
        to ``timeout``; False means the update was NOT accepted (timed out,
        stale, invalid, or the server is stopping)."""
        if not (0 <= worker_id < self.m) or not (0 <= round < self.T):
            self.metrics.inc("updates_invalid")
            return False
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._admit:
            while (round >= self._round + self.cfg.lookahead_rounds
                   and not self._stop.is_set()
                   and not self._draining.is_set()
                   and not self._done.is_set()):
                wait = (None if deadline is None
                        else deadline - time.monotonic())
                if wait is not None and wait <= 0:
                    self.metrics.inc("updates_backpressured")
                    return False
                self._admit.wait(wait)
            if (self._stop.is_set() or self._draining.is_set()
                    or self._done.is_set()):
                self.metrics.inc("updates_rejected_shutdown")
                return False
            if round < self._round:
                self.metrics.inc("updates_stale_dropped")
                return False
        remaining = (None if deadline is None
                     else max(deadline - time.monotonic(), 0.0))
        ok = self.ring.put(Update(worker_id, round, payload, time.monotonic()),
                           timeout=remaining)
        if not ok:
            self.metrics.inc("updates_backpressured")
        return ok

    # ------------------------------------------------------------- loop

    def start(self) -> None:
        if self.cfg.health_port is not None and self.health is None:
            self.health = HealthEndpoint(self.snapshot,
                                         port=self.cfg.health_port)
            self.health.start()
        self._thread = threading.Thread(target=self._run, name="serve-loop",
                                        daemon=True)
        self._thread.start()

    def _run(self) -> None:
        try:
            self._loop()
        except BaseException as e:  # surfaced via .error / /health status
            self.error = e
            self._log.write({"event": "error", "error": repr(e),
                             "round": self._round})
        finally:
            self._done.set()
            self.ring.close()
            with self._admit:
                self._admit.notify_all()
            self._log.write({"event": "stopped", "round": self._round,
                             **self.metrics.snapshot()})

    def _loop(self) -> None:
        while not self._stop.is_set() and self._round < self.T:
            msg = self.ring.get(timeout=self.cfg.poll_s)
            if self._stop.is_set():
                break
            if msg is not None:
                self._ingest(msg)
            progressed = self._maybe_process()
            if (self._draining.is_set() and msg is None and not progressed
                    and len(self.ring) == 0):
                # quiescent drain: nothing queued, current round not
                # complete-able. With a round timeout, a partial round will
                # still trip its deadline — keep looping; without one, a
                # partial final round is abandoned (nothing more can arrive).
                if (not self._pending.get(self._round)
                        or self.cfg.round_timeout_s is None):
                    break
        if not self._stop.is_set() and self.cfg.checkpoint_dir:
            # graceful exit (drain or natural completion): final checkpoint
            # at the exact round boundary -> bitwise resume
            self._checkpoint()

    def _ingest(self, msg: Update) -> None:
        self.metrics.observe_staleness(time.monotonic() - msg.sent_at)
        if msg.round < self._round:
            self.metrics.inc("updates_stale_dropped")
            return
        slot = self._pending.setdefault(msg.round, {})
        if msg.worker_id in slot:
            self.metrics.inc("updates_duplicate")
        slot[msg.worker_id] = msg.payload
        self.metrics.inc("updates_accepted")

    def _maybe_process(self) -> bool:
        r = self._round
        got = self._pending.get(r)
        if not got:
            self._deadline = None
            return False
        if self.cfg.round_timeout_s is not None and self._deadline is None:
            self._deadline = time.monotonic() + self.cfg.round_timeout_s
        full = len(got) == self.m
        timed_out = (self._deadline is not None
                     and time.monotonic() >= self._deadline
                     and len(got) >= self.cfg.min_workers)
        if not (full or timed_out):
            return False
        self._process_round(r, self._pending.pop(r))
        return True

    def _process_round(self, r: int, got: Dict[int, Any]) -> None:
        t0 = time.perf_counter()
        stragglers = [i for i in range(self.m) if i not in got]
        if stragglers:
            # a timed-out worker is a dynamically-Byzantine one: zero-fill
            # its batch slot (inert — the mask makes the aggregator discard
            # whatever that slot produces) and OR it into the round's mask
            zeros = jax.tree.map(jnp.zeros_like, next(iter(got.values())))
            masks = np.array(self.sched.masks[r])
            masks[..., stragglers] = True
            self.metrics.inc("stragglers_masked", len(stragglers))
        else:
            masks = self.sched.masks[r]
        payloads = [got.get(i, zeros if stragglers else None)
                    for i in range(self.m)]
        batches = jax.tree.map(lambda *ls: jnp.stack(ls), *payloads)
        inputs = RoundInputs(r, int(self.sched.levels[r]), batches, masks,
                             self.sched.keys[r])
        self.carry, info = self.session.step(self.carry, inputs)
        j = int(self.sched.levels[r])
        self.logs.append(RoundLog(j, bool(info.failsafe_ok),
                                  int(np.asarray(masks)[0].sum()),
                                  round_cost(j, self.session.cfg.mlmc.j_max)))
        if not info.failsafe_ok and j >= 1:
            self.metrics.inc("failsafe_trips")
        self.metrics.inc("rounds_completed")
        self.metrics.mark_updates(len(got))
        self.metrics.set("last_round_s", round(time.perf_counter() - t0, 6))
        with self._admit:
            self._round = r + 1
            self._admit.notify_all()
        self._deadline = None
        self._log.write({"event": "round", "round": r, "level": j,
                         "workers": len(got), "stragglers": len(stragglers),
                         "failsafe_ok": bool(info.failsafe_ok),
                         "step_s": round(time.perf_counter() - t0, 6)})
        if (self.cfg.checkpoint_every and self.cfg.checkpoint_dir
                and (r + 1) % self.cfg.checkpoint_every == 0):
            self._checkpoint()

    def _checkpoint(self) -> None:
        step = self._round
        if step == self._last_ckpt:
            return
        path = os.path.join(self.cfg.checkpoint_dir, f"carry_{step:06d}")
        save_checkpoint(path, self.carry, step=step)
        self._last_ckpt = step
        self.metrics.inc("checkpoints_written")
        self._log.write({"event": "checkpoint", "round": step, "path": path})

    # ---------------------------------------------------------- lifecycle

    def stop(self, drain: bool = True, timeout: Optional[float] = 60.0) -> bool:
        """Stop the loop. ``drain=True``: process everything already
        submitted, then write a final checkpoint (graceful, bitwise-
        resumable). ``drain=False``: kill — the in-flight round finishes,
        queued messages are dropped, NO final checkpoint (resume replays
        from the last periodic one). Returns True if the loop exited within
        ``timeout``."""
        if drain:
            self._draining.set()
        else:
            self._stop.set()
            self.ring.close()
        with self._admit:
            self._admit.notify_all()
        if self._thread is None:  # never started: no loop to wait out
            self._done.set()
            self.ring.close()
        done = self._done.wait(timeout)
        self._log.write({"event": "drained" if drain else "killed",
                         "round": self._round})
        return done

    def join(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)

    def close(self) -> None:
        """Tear down everything (idempotent): loop, health endpoint, log."""
        if not self._done.is_set():
            self.stop(drain=False)
        if self.health is not None:
            self.health.stop()
            self.health = None
        self._log.close()

    # ------------------------------------------------------------ status

    def _status(self) -> str:
        if self.error is not None:
            return "error"
        if self._done.is_set():
            return "stopped" if self._round < self.T else "completed"
        if self._draining.is_set():
            return "draining"
        return "live"

    @property
    def round(self) -> int:
        with self._admit:
            return self._round

    def snapshot(self) -> Dict[str, Any]:
        """The health/metrics view (thread-safe; served over HTTP)."""
        snap = self.metrics.snapshot()
        snap.update(self.ring.stats())
        r = self.round
        snap.update(status=self._status(), round=r, rounds_total=self.T,
                    rounds_completed=r - self.start_round,
                    pending_rounds=len(self._pending), workers=self.m,
                    start_round=self.start_round)
        return snap

    @property
    def params(self):
        return self.carry[0]
