"""Lightweight HTTP health/metrics endpoint for the aggregation server
(DESIGN.md §10): ``GET /health`` answers liveness + round progress, ``GET
/metrics`` the full metrics snapshot, both as JSON. Stdlib-only
(``http.server`` on a daemon thread); port 0 binds an ephemeral port."""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict

_HEALTH_KEYS = ("status", "round", "rounds_total", "rounds_completed",
                "updates_accepted", "updates_per_sec")


class HealthEndpoint:
    """Serve ``snapshot_fn()`` over HTTP. The callable must be cheap and
    thread-safe — it runs on request-handler threads."""

    def __init__(self, snapshot_fn: Callable[[], Dict[str, Any]],
                 host: str = "127.0.0.1", port: int = 0):
        self._snapshot_fn = snapshot_fn
        endpoint = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (http.server API)
                try:
                    snap = endpoint._snapshot_fn()
                except Exception as e:  # surface, don't kill the handler
                    self._reply(500, {"status": "error", "error": repr(e)})
                    return
                if self.path.rstrip("/") in ("", "/health"):
                    body = {k: snap[k] for k in _HEALTH_KEYS if k in snap}
                    body.setdefault("status", "live")
                    self._reply(200, body)
                elif self.path.rstrip("/") == "/metrics":
                    self._reply(200, snap)
                else:
                    self._reply(404, {"error": f"no route {self.path!r}"})

            def _reply(self, code: int, body: Dict[str, Any]):
                data = json.dumps(body).encode()
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def log_message(self, *a):  # keep request noise out of stderr
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: threading.Thread | None = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> None:
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="serve-health", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
