"""Simulated worker clients for the aggregation server (DESIGN.md §10).

``worker_payloads`` slices the session's own round schedule into per-worker
messages — exactly the (n_max-padded) batch slice worker ``i`` would have
drawn locally, so a fully-delivered stream reassembles (``jnp.stack`` over
workers is the inverse of the slicing) into bit-for-bit the offline driver's
batch tree. ``SimulatedWorkers`` runs one producer thread per worker pushing
those messages through ``AggregationServer.submit``, with optional
per-message jitter (exercises out-of-order arrival across rounds within the
lookahead window) and a drop set (exercises the straggler-timeout path).
"""
from __future__ import annotations

import random
import threading
import time
from typing import Any, Iterable, List, Optional, Sequence, Tuple

import jax


def worker_payloads(session, T: int, start: int = 0) -> List[List[Any]]:
    """``rounds[t - start][i]`` = worker ``i``'s payload for round ``t``,
    sliced from ``session.round_inputs`` (leading worker axis dropped). The
    list is what a replay after checkpoint-resume feeds from ``start``."""
    sched = session.schedule(T)
    if session.m is None:
        raise ValueError("worker payloads need the session's worker count; "
                         "build it with switcher= or m=")
    rounds = []
    for t in range(start, T):
        batches = session.round_inputs(sched, t).batches
        rounds.append([jax.tree.map(lambda l, i=i: l[i], batches)
                       for i in range(session.m)])
    return rounds


class SimulatedWorkers:
    """One daemon producer thread per worker, each submitting its payload
    stream in round order (the server tolerates cross-worker reordering up
    to its lookahead window). ``drop`` is a set of ``(worker_id, round)``
    pairs to silently skip — those workers become stragglers and get masked
    once the round deadline fires. Failed submits (backpressure timeout or
    server shutdown) are collected in ``failures``."""

    def __init__(self, server, payloads: Sequence[Sequence[Any]], *,
                 start_round: int = 0,
                 drop: Optional[Iterable[Tuple[int, int]]] = None,
                 jitter_s: float = 0.0, seed: int = 0,
                 submit_timeout: Optional[float] = 60.0):
        self.server = server
        self.payloads = payloads
        self.start_round = start_round
        self.drop = frozenset(drop or ())
        self.jitter_s = jitter_s
        self.seed = seed
        self.submit_timeout = submit_timeout
        self.failures: List[Tuple[int, int]] = []
        self._lock = threading.Lock()
        self._threads: List[threading.Thread] = []

    def _run_worker(self, wid: int) -> None:
        rng = random.Random(self.seed * 1_000 + wid)
        for off, per_worker in enumerate(self.payloads):
            t = self.start_round + off
            if (wid, t) in self.drop:
                continue
            if self.jitter_s:
                time.sleep(rng.uniform(0.0, self.jitter_s))
            ok = self.server.submit(wid, t, per_worker[wid],
                                    timeout=self.submit_timeout)
            if not ok:
                with self._lock:
                    self.failures.append((wid, t))

    def start(self) -> "SimulatedWorkers":
        m = self.server.m
        self._threads = [
            threading.Thread(target=self._run_worker, args=(i,),
                             name=f"serve-worker-{i}", daemon=True)
            for i in range(m)
        ]
        for th in self._threads:
            th.start()
        return self

    def join(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        for th in self._threads:
            th.join(None if deadline is None
                    else max(deadline - time.monotonic(), 0.0))
        return not any(th.is_alive() for th in self._threads)
