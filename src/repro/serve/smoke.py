"""Serve smoke test — ``PYTHONPATH=src python -m repro.serve.smoke``.

Launches the aggregation server on the 2D quadratic testbed with 16
simulated workers, pushes a few hundred updates through the ring, polls the
HTTP health endpoint until the stream completes, asserts the served carry is
bitwise-identical to the offline compiled driver, and shuts down cleanly.
Exit code 0 on success; this is the CI ``serve-smoke`` step.
"""
from __future__ import annotations

import json
import sys
import tempfile
import time
import urllib.request

import numpy as np

from repro.api import build_session
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig
from repro.core.scenarios import make_quadratic_task
from repro.core.switching import get_switcher
from repro.optim.optimizers import adagrad_norm
from repro.serve import AggregationServer, ServeConfig, SimulatedWorkers
from repro.serve.client import worker_payloads

M, T, SEED = 16, 32, 7


def main() -> int:
    task = make_quadratic_task()
    cfg = DynaBROConfig(mlmc=MLMCConfig(T=T, m=M, V=3.0, kappa=1.0, j_cap=2),
                        aggregator="cwmed", delta=0.4, attack="sign_flip")
    switcher = get_switcher("periodic", M, n_byz=4, K=5, seed=SEED)

    def session():
        return build_session(cfg, task, switcher=switcher,
                             opt=adagrad_norm(2e-2), seed=SEED)

    # offline reference: the whole-T compiled driver on the same session
    params_ref, logs_ref, _ = session().run(T)

    sess = session()
    with tempfile.NamedTemporaryFile(mode="r", suffix=".jsonl") as logf:
        server = AggregationServer(sess, T, ServeConfig(
            capacity=256, lookahead_rounds=4, health_port=0,
            metrics_log=logf.name))
        server.start()
        workers = SimulatedWorkers(
            server, worker_payloads(sess, T), jitter_s=0.002).start()
        url = server.health.url

        deadline = time.monotonic() + 120.0
        health = {}
        while time.monotonic() < deadline:
            with urllib.request.urlopen(url + "/health", timeout=5) as r:
                health = json.load(r)
            assert health["status"] in ("live", "draining", "completed"), health
            if health["round"] >= T:
                break
            time.sleep(0.05)
        assert health.get("round") == T, f"stream stalled: {health}"
        assert health["rounds_completed"] == T, health
        assert health["updates_accepted"] == M * T, health

        if not workers.join(timeout=30.0) or workers.failures:
            print(f"worker failures: {workers.failures}", file=sys.stderr)
            return 1
        server.stop(drain=True)
        snap = server.snapshot()
        events = [json.loads(ln) for ln in logf.readlines() if ln.strip()]
        server.close()

    if server.error is not None:
        print(f"server error: {server.error!r}", file=sys.stderr)
        return 1
    for a, b in zip(np.asarray(server.params["x"]),
                    np.asarray(params_ref["x"])):
        assert a == b, (server.params, params_ref)
    assert [(lg.level, lg.failsafe_ok) for lg in server.logs] == \
           [(lg.level, lg.failsafe_ok) for lg in logs_ref]
    assert sum(1 for e in events if e.get("event") == "round") == T
    print(f"serve smoke OK: {T} rounds x {M} workers bitwise == offline "
          f"driver; {snap['updates_per_sec']:.0f} updates/s, ring high-water "
          f"{snap['ring_high_water']}/{snap['ring_capacity']}, staleness "
          f"mean {snap['staleness_mean_s'] * 1e3:.2f} ms")
    return 0


if __name__ == "__main__":
    sys.exit(main())
