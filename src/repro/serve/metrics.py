"""Serve-side metrics: thread-safe counters/gauges, a sliding-window
updates/sec throughput estimate, staleness observation, and a structured
JSONL metrics log (DESIGN.md §10)."""
from __future__ import annotations

import collections
import json
import threading
import time
from typing import Any, Dict, Optional


class ServeMetrics:
    """Counters + gauges + derived rates behind one lock.

    ``mark_updates(n)`` feeds the throughput window (accepted updates,
    stamped with the monotonic clock); ``updates_per_sec()`` is the rate over
    the last ``window_s`` seconds. ``observe_staleness`` tracks message age
    (submit -> ingest) as a running mean plus max."""

    def __init__(self, window_s: float = 10.0):
        self._lock = threading.Lock()
        self._window_s = window_s
        self._counts: Dict[str, int] = collections.defaultdict(int)
        self._gauges: Dict[str, Any] = {}
        self._events: collections.deque = collections.deque()  # (t, n)
        self._stale_sum = 0.0
        self._stale_n = 0
        self._stale_max = 0.0
        self._t0 = time.monotonic()

    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counts[name] += n

    def set(self, name: str, value: Any) -> None:
        with self._lock:
            self._gauges[name] = value

    def mark_updates(self, n: int) -> None:
        now = time.monotonic()
        with self._lock:
            self._events.append((now, n))
            self._trim(now)

    def observe_staleness(self, age_s: float) -> None:
        with self._lock:
            self._stale_sum += age_s
            self._stale_n += 1
            self._stale_max = max(self._stale_max, age_s)

    def _trim(self, now: float) -> None:
        cutoff = now - self._window_s
        while self._events and self._events[0][0] < cutoff:
            self._events.popleft()

    def updates_per_sec(self) -> float:
        now = time.monotonic()
        with self._lock:
            self._trim(now)
            total = sum(n for _, n in self._events)
            # early on, the window hasn't filled yet — rate over elapsed time
            span = min(self._window_s, max(now - self._t0, 1e-9))
            return total / span

    def snapshot(self) -> Dict[str, Any]:
        ups = self.updates_per_sec()
        with self._lock:
            out: Dict[str, Any] = dict(self._counts)
            out.update(self._gauges)
            out["updates_per_sec"] = round(ups, 3)
            out["staleness_mean_s"] = (
                round(self._stale_sum / self._stale_n, 6)
                if self._stale_n else 0.0)
            out["staleness_max_s"] = round(self._stale_max, 6)
            return out


class MetricsLog:
    """Append-only JSONL structured metrics log: one record per event
    (round processed, checkpoint written, drain, ...), each stamped with
    wall-clock time. Thread-safe; ``None``-path constructs a no-op."""

    def __init__(self, path: Optional[str]):
        self._path = path
        self._lock = threading.Lock()
        self._f = open(path, "a") if path else None

    def write(self, record: Dict[str, Any]) -> None:
        if self._f is None:
            return
        record = {"ts": time.time(), **record}
        with self._lock:
            self._f.write(json.dumps(record) + "\n")
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None
