"""End-to-end Mode B driver: distributed DynaBRO on a (simulated) mesh.

Trains a reduced llama-family model with FSDP + tensor parallelism and the
robust all-to-all aggregation, one Byzantine worker sign-flipping, with full
MLMC levels and the fail-safe filter — the production path of
``repro.launch.train`` (this example just invokes it with a CPU-sized mesh).

  PYTHONPATH=src python examples/train_multipod.py
"""
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def main():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen3-0.6b", "--reduced",
           "--devices", "8", "--mesh", "2x2x2",  # pod x data x model
           "--steps", "30", "--global-batch", "8", "--seq-len", "128",
           "--mlmc", "--aggregator", "cwmed", "--attack", "sign_flip",
           "--switch", "periodic", "--switch-k", "5", "--n-byz", "1",
           "--ckpt-every", "15"]
    print("+", " ".join(cmd))
    sys.exit(subprocess.call(cmd, env=env, cwd=ROOT))


if __name__ == "__main__":
    main()
