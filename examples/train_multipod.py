"""End-to-end distributed DynaBRO on a (simulated) mesh — both halves.

1. **Mode B** (production scale-out): trains a reduced llama-family model
   with FSDP + tensor parallelism and the robust all-to-all aggregation, one
   Byzantine worker sign-flipping, full MLMC levels and the fail-safe filter
   — the production path of ``repro.launch.train``.
2. **Mode A, sharded compiled driver** (DESIGN.md §7): the whole T-round
   Algorithm-2 loop compiled under a fully-manual ``shard_map``, the m
   simulated workers laid out across a 4-device ``workers`` mesh, checked
   bitwise against the single-device ``run_dynabro_scan``.

Both run on CPU with forced host devices:

  PYTHONPATH=src python examples/train_multipod.py            # both demos
  PYTHONPATH=src python examples/train_multipod.py --mode b   # Mode B only
"""
import argparse
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")

SHARDED_SCAN_DEMO = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import time
import jax, numpy as np
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig, run_dynabro_scan
from repro.core.scenarios import make_quadratic_task
from repro.core.switching import get_switcher
from repro.launch.mesh import make_worker_mesh
from repro.optim.optimizers import sgd

T, m = 300, 8
task = make_quadratic_task()
cfg = DynaBROConfig(mlmc=MLMCConfig(T=T, m=m, V=3.0, kappa=1.0),
                    aggregator="cwtm", delta=0.3, attack="alie")
sw = lambda: get_switcher("periodic", m, n_byz=2, K=25)
sampler = task.make_sampler(m)
mesh = make_worker_mesh(4)
print(f"mesh={mesh.shape} workers(m)={m} T={T} attack=alie agg=cwtm")
t0 = time.time()
p_sh, logs, _ = run_dynabro_scan(task.grad_fn, task.params0, sgd(2e-2), cfg,
                                 sw(), sampler, T, seed=0, mesh=mesh)
print(f"sharded scan: f(x_T)={task.objective(p_sh):.5f} "
      f"({time.time()-t0:.1f}s, {sum(l.cost for l in logs)} grad evals/worker)")
p_1d, _, _ = run_dynabro_scan(task.grad_fn, task.params0, sgd(2e-2), cfg,
                              sw(), sampler, T, seed=0)
same = bool((np.asarray(p_sh["x"]) == np.asarray(p_1d["x"])).all())
print("bitwise parity vs single-device driver:", same)
assert same
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="both", choices=["a", "b", "both"])
    args = ap.parse_args()
    env = dict(os.environ)
    # prepend (don't clobber): a pip-installed repro works without this, and
    # an existing PYTHONPATH keeps working with it
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(ROOT, "src"), env.get("PYTHONPATH")) if p)
    rc = 0
    if args.mode in ("a", "both"):
        print("== Mode A: sharded compiled driver (4-device workers mesh) ==")
        rc = subprocess.call([sys.executable, "-c", SHARDED_SCAN_DEMO],
                             env=env, cwd=ROOT)
        if rc:
            sys.exit(rc)
    if args.mode in ("b", "both"):
        print("== Mode B: FSDP + tensor-parallel robust training ==")
        cmd = [sys.executable, "-m", "repro.launch.train",
               "--arch", "qwen3-0.6b", "--reduced",
               "--devices", "8", "--mesh", "2x2x2",  # pod x data x model
               "--steps", "30", "--global-batch", "8", "--seq-len", "128",
               "--mlmc", "--aggregator", "cwmed", "--attack", "sign_flip",
               "--switch", "periodic", "--switch-k", "5", "--n-byz", "1",
               "--ckpt-every", "15"]
        print("+", " ".join(cmd))
        rc = subprocess.call(cmd, env=env, cwd=ROOT)
    sys.exit(rc)


if __name__ == "__main__":
    main()
