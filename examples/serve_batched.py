"""Batched serving: prefill a batch of prompts, then greedy-decode new tokens
with the KV/state cache (works for every family — attention ring-buffers,
mamba conv+ssm state, rwkv wkv state).

  pip install -e . && python examples/serve_batched.py [--arch rwkv6-1.6b]
  (or, without installing:  PYTHONPATH=src python examples/serve_batched.py)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    # one subkey per consumer — reusing one key correlates the prompt draw
    # with the weight init (jaxlint JXL001)
    kp, kt, kx = jax.random.split(jax.random.PRNGKey(0), 3)
    params = init_params(cfg, kp)
    prompts = jax.random.randint(kt, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    extra = None
    if cfg.family == "audio":
        extra = {"frames": 0.1 * jax.random.normal(
            kx, (args.batch, cfg.encoder_seq, cfg.d_model))}
    elif cfg.family == "vlm":
        extra = {"patches": 0.1 * jax.random.normal(
            kx, (args.batch, cfg.n_image_tokens, cfg.d_model))}

    total = args.prompt_len + args.tokens
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, t: prefill(p, t, cfg, extra=extra, pad_to=total))(params, prompts)
    print(f"prefill [{args.batch}x{args.prompt_len}] in {time.time()-t0:.2f}s")

    step = jax.jit(lambda p, c, tok, pos: decode_step(p, c, tok, pos, cfg))
    tok = jnp.argmax(logits, -1)
    out = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        logits, cache = step(params, cache, tok, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, -1)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.stack(out, 1)
    print(f"decoded {args.tokens-1} x {args.batch} tokens in {dt:.2f}s "
          f"({(args.tokens-1)*args.batch/dt:.1f} tok/s on CPU)")
    print("sequences:\n", gen)


if __name__ == "__main__":
    main()
