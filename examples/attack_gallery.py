"""Attack × aggregator gallery: who survives what?

Sweeps the paper's attacks (SF / IPM / ALIE) — including kwarg variants like
a strong ``ipm(eps=0.9)`` and the Baruch et al. auto-z ``alie(z=None)`` —
against every aggregation rule on the quadratic testbed under dynamic
(Periodic) switching. Aggregator *hyperparameters* are a grid axis of their
own (DESIGN.md §4): CWTM runs at two trim levels ``cwtm(delta=...)`` exactly
like attack kwarg variants. Runs through ``run_matrix(driver="vmap")``: the
ENTIRE grid — every attack, rule and hyperparameter variant — is lanes of
ONE vmapped compiled call (per-lane attack AND aggregator dispatch,
DESIGN.md §7). Prints a survival matrix of final optimality gaps with
kwarg-qualified columns and lines.

  PYTHONPATH=src python examples/attack_gallery.py
"""

from repro.core.scenarios import (
    format_table, make_quadratic_task, run_matrix, scenario_grid,
)


def main():
    m, n_byz, T = 9, 3, 250
    delta = round(n_byz / m + 0.01, 3)
    aggs = ["mean", "cwmed", ("cwtm", {"delta": 0.15}),
            ("cwtm", {"delta": delta}), ("cwtm", {"delta": 0.45}),
            "krum", "geomed", "nnm+cwmed", "mfm"]
    attacks = ["sign_flip", ("ipm", {"eps": 0.1}), ("ipm", {"eps": 0.9}),
               "alie", ("alie", {"z": None})]
    switchers = [("periodic", {"n_byz": n_byz, "K": 20})]
    task = make_quadratic_task()
    rows = run_matrix(task, scenario_grid(attacks, switchers, aggs),
                      m=m, T=T, V=3.0, delta=delta, j_cap=4, driver="vmap")
    print(format_table(rows))
    total_wall = sum(r["wall_s"] for r in rows)
    print(f"\n(gap ≈ 0 => survived; mean should fail, robust rules survive, "
          f"under-trimmed cwtm(delta=0.15) sits in between; {len(rows)} "
          f"scenarios in {total_wall:.1f}s — the whole grid is ONE vmapped "
          f"dispatch)")


if __name__ == "__main__":
    main()
