"""Attack × aggregator gallery: who survives what?

Sweeps the paper's attacks (SF / IPM / ALIE) — including kwarg variants like
a strong ``ipm(eps=0.9)`` and the Baruch et al. auto-z ``alie(z=None)`` —
against every aggregation rule on the quadratic testbed under dynamic
(Periodic) switching. Runs through ``run_matrix(driver="vmap")``: all attack
variants of an aggregator are lanes of ONE vmapped compiled call (per-lane
attack dispatch, DESIGN.md §7), so the whole grid costs one dispatch per
aggregator. Prints a survival matrix of final optimality gaps with
kwarg-qualified columns.

  PYTHONPATH=src python examples/attack_gallery.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.scenarios import (
    format_table, make_quadratic_task, run_matrix, scenario_grid,
)


def main():
    m, n_byz, T = 9, 3, 250
    aggs = ["mean", "cwmed", "cwtm", "krum", "geomed", "nnm+cwmed", "mfm"]
    attacks = ["sign_flip", ("ipm", {"eps": 0.1}), ("ipm", {"eps": 0.9}),
               "alie", ("alie", {"z": None})]
    switchers = [("periodic", {"n_byz": n_byz, "K": 20})]
    task = make_quadratic_task()
    rows = run_matrix(task, scenario_grid(attacks, switchers, aggs),
                      m=m, T=T, V=3.0, delta=n_byz / m + 0.01, j_cap=4,
                      driver="vmap")
    print(format_table(rows))
    total_wall = sum(r["wall_s"] for r in rows)
    print(f"\n(gap ≈ 0 => survived; mean should fail, robust rules survive; "
          f"{len(rows)} scenarios in {total_wall:.1f}s — one vmapped dispatch "
          f"per aggregator)")


if __name__ == "__main__":
    main()
