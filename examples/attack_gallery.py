"""Attack × aggregator gallery: who survives what?

Sweeps the paper's attacks (SF / IPM / ALIE) against every aggregation rule
on the quadratic testbed, under static and dynamic (Periodic) switching.
Prints a survival matrix of final optimality gaps.

  PYTHONPATH=src python examples/attack_gallery.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig, run_dynabro
from repro.core.switching import get_switcher
from repro.optim.optimizers import sgd

A = jnp.array([[2.0, 1.0], [1.0, 2.0]])
P0 = {"x": jnp.array([3.0, -2.0])}


def grad_fn(params, unit_key):
    return {"x": A @ params["x"] + 0.5 * jax.random.normal(unit_key, (2,))}


def sampler(m, seed=0):
    def sample(t, n):
        keys = jax.random.split(jax.random.fold_in(jax.random.PRNGKey(seed), t), m * n)
        return keys.reshape(m, n, *keys.shape[1:])
    return sample


def main():
    m, n_byz, T = 9, 3, 250
    aggs = ["mean", "cwmed", "cwtm", "krum", "geomed", "nnm+cwmed", "mfm"]
    attacks = ["sign_flip", "ipm", "alie"]
    print(f"{'':12s}" + "".join(f"{a:>12s}" for a in attacks))
    for agg in aggs:
        row = []
        for atk in attacks:
            cfg = DynaBROConfig(
                mlmc=MLMCConfig(T=T, m=m, V=3.0, option=2 if agg == "mfm" else 1,
                                kappa=1.0, j_cap=4),
                aggregator=agg, delta=n_byz / m + 0.01, attack=atk)
            sw = get_switcher("periodic", m, n_byz=n_byz, K=20)
            p, _, _ = run_dynabro(grad_fn, P0, sgd(2e-2), cfg, sw, sampler(m), T)
            row.append(float(0.5 * p["x"] @ A @ p["x"]))
        print(f"{agg:12s}" + "".join(f"{v:12.4f}" for v in row))
    print("\n(gap ≈ 0 => survived; mean should fail, robust rules survive)")


if __name__ == "__main__":
    main()
