"""The aggregation service end to end (DESIGN.md §10): 16 simulated workers
stream per-round updates into the server's ring buffer; the server drains
them through the jitted session step, masks workers that miss the round
deadline as dynamically Byzantine, checkpoints the carry every 16 rounds,
and serves live health over HTTP while training runs. Finishes by verifying
the streamed result is bitwise-identical to the offline compiled driver.

  pip install -e . && python examples/serve_aggregation.py
  (or, without installing:  PYTHONPATH=src python examples/serve_aggregation.py)
"""
import json
import tempfile
import time
import urllib.request

import numpy as np

from repro.api import (
    DynaBROConfig, MLMCConfig, adagrad_norm, build_session, get_switcher,
    make_quadratic_task,
)
from repro.serve import (
    AggregationServer, ServeConfig, SimulatedWorkers, worker_payloads,
)

M, T, SEED = 16, 64, 0


def main():
    task = make_quadratic_task()
    cfg = DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=M, V=3.0, kappa=1.0, j_cap=3),
        aggregator="cwtm", delta=0.3, attack="sign_flip")
    switcher = get_switcher("periodic", M, n_byz=4, K=8, seed=SEED)

    def session():
        return build_session(cfg, task, switcher=switcher,
                             opt=adagrad_norm(5e-2), seed=SEED)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        sess = session()
        server = AggregationServer(sess, T, ServeConfig(
            capacity=256, lookahead_rounds=8, round_timeout_s=1.0,
            checkpoint_every=16, checkpoint_dir=ckpt_dir, health_port=0))
        server.start()
        print(f"health endpoint: {server.health.url}/health")

        # worker 3 drops round 20 -> masked as Byzantine for that round only
        workers = SimulatedWorkers(server, worker_payloads(sess, T),
                                   jitter_s=0.003, drop={(3, 20)}).start()
        while server.round < T:
            with urllib.request.urlopen(server.health.url + "/health",
                                        timeout=5) as r:
                h = json.load(r)
            print(f"  status={h['status']} round={h['round']}/{T} "
                  f"{h['updates_per_sec']:.0f} updates/s")
            time.sleep(0.5)
        workers.join(timeout=60.0)
        server.stop(drain=True)
        snap = server.snapshot()
        server.close()

        print(f"\nstreamed {snap['updates_accepted']} updates, "
              f"{snap['stragglers_masked']} straggler masked, "
              f"{snap['checkpoints_written']} checkpoints, ring high-water "
              f"{snap['ring_high_water']}/{snap['ring_capacity']}")
        print("objective gap:", task.objective(server.params))

        params_ref, _, _ = session().run(T)
        # worker 3's dropped round makes the server stream differ from the
        # undisturbed offline run -- so compare against an offline replay is
        # the tests' job; with no drops the streams match bitwise:
        sess2 = build_session(cfg, task, switcher=switcher,
                              opt=adagrad_norm(5e-2), seed=SEED)
        server2 = AggregationServer(sess2, T)
        server2.start()
        SimulatedWorkers(server2, worker_payloads(sess2, T)).start().join(60.0)
        server2.join(timeout=60.0)
        server2.close()
        same = all(np.array_equal(a, b) for a, b in
                   zip(np.asarray(server2.params["x"]),
                       np.asarray(params_ref["x"])))
        print("undisturbed stream bitwise == offline driver:", same)
        assert same


if __name__ == "__main__":
    main()
