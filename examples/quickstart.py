"""Quickstart: Byzantine-robust training in ~40 lines (Mode A simulation).

Runs DynaBRO (Algorithm 2) on a small classifier with m=17 workers of which 8
are Byzantine (sign-flip), under the Periodic(10) identity-switching strategy
— the paper's Figure 1 setting, shrunk to run in ~a minute on CPU. Uses the
``repro.api`` session facade (DESIGN.md §10).

  pip install -e .  &&  python examples/quickstart.py
  (or, without installing:  PYTHONPATH=src python examples/quickstart.py)
"""
from repro.api import DynaBROConfig, MLMCConfig, build_session, get_switcher, sgd
from repro.data.classification import make_task


def main():
    m, n_byz, T = 17, 8, 150
    params0, grad_fn, sampler, eval_fn = make_task(m, seed=0)

    cfg = DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=m, V=5.0, option=1, kappa=1.0, j_cap=5),
        aggregator="cwtm",          # coordinate-wise trimmed mean
        delta=n_byz / m + 1e-3,
        attack="sign_flip")          # Byzantine workers negate their gradients

    session = build_session(
        cfg, switcher=get_switcher("periodic", m, n_byz=n_byz, K=10),
        grad_fn=grad_fn, params0=params0, sample_batches=sampler,
        opt=sgd(0.1))
    params, logs, evals = session.run(T, eval_fn=eval_fn, eval_every=30)

    for t, ev in evals:
        print(f"round {t:4d}  test_acc={ev['test_acc']:.3f}")
    levels = [l.level for l in logs]
    print(f"\nMLMC levels used: {sorted(set(levels))}, "
          f"mean per-worker cost/round: "
          f"{sum(l.cost for l in logs) / len(logs):.2f} gradient evals")
    acc = evals[-1][1]["test_acc"]
    print("final accuracy:", acc, "(>0.8 expected despite 8/17 Byzantine)")


if __name__ == "__main__":
    main()
