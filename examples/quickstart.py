"""Quickstart: Byzantine-robust training in ~40 lines (Mode A simulation).

Runs DynaBRO (Algorithm 2) on a small classifier with m=17 workers of which 8
are Byzantine (sign-flip), under the Periodic(10) identity-switching strategy
— the paper's Figure 1 setting, shrunk to run in ~a minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks._clf import make_task
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig, run_dynabro
from repro.core.switching import get_switcher
from repro.optim.optimizers import sgd


def main():
    m, n_byz, T = 17, 8, 150
    params0, grad_fn, sampler, eval_fn = make_task(m, seed=0)

    cfg = DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=m, V=5.0, option=1, kappa=1.0, j_cap=5),
        aggregator="cwtm",          # coordinate-wise trimmed mean
        delta=n_byz / m + 1e-3,
        attack="sign_flip")          # Byzantine workers negate their gradients

    switcher = get_switcher("periodic", m, n_byz=n_byz, K=10)

    params, logs, evals = run_dynabro(
        grad_fn, params0, sgd(0.1), cfg, switcher, sampler, T,
        eval_fn=eval_fn, eval_every=30)

    for t, ev in evals:
        print(f"round {t:4d}  test_acc={ev['test_acc']:.3f}")
    levels = [l.level for l in logs]
    print(f"\nMLMC levels used: {sorted(set(levels))}, "
          f"mean per-worker cost/round: "
          f"{sum(l.cost for l in logs) / len(logs):.2f} gradient evals")
    acc = evals[-1][1]["test_acc"]
    print("final accuracy:", acc, "(>0.8 expected despite 8/17 Byzantine)")


if __name__ == "__main__":
    main()
