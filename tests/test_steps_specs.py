"""Satellite-1 regression (PR 7): both Mode-B train-step builders must derive
their batch specs / example inputs from the ONE shared builder
(``launch.sharding.batch_sds``) for EVERY config family — the old duplicated
spec code dropped the audio/vlm ``extra`` leaves from the MLMC path, so
``build_mlmc_train_step`` could not run the whisper / vision configs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced_config
from repro.configs.base import ShapeConfig
from repro.core.mlmc import MLMCConfig
from repro.launch.steps import build_mlmc_train_step, build_train_step

# one arch per family: dense, moe, hybrid, ssm, audio, vlm
FAMILY_ARCHS = [
    "smollm-360m",
    "qwen2-moe-a2.7b",
    "jamba-1.5-large-398b",
    "rwkv6-1.6b",
    "whisper-base",
    "llama-3.2-vision-90b",
]


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_mlmc_batch_sds_matches_train_step(arch):
    cfg = get_reduced_config(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("t", 16, 4, "train")
    mc = MLMCConfig(T=8, m=1, V=1e9)
    bs = build_train_step(cfg, mesh, shape, dtype=jnp.float32)
    bm = build_mlmc_train_step(cfg, mesh, shape, mc, 1, dtype=jnp.float32)
    b1, b2 = bs.inputs[2], bm.inputs[2]
    # identical pytree structure — in particular the family 'extra' leaves
    assert jax.tree.structure(b1) == jax.tree.structure(b2)
    if cfg.family in ("audio", "vlm"):
        assert "extra" in b2, "MLMC step dropped the family extra leaves"
    # MLMC level J=1 scales only the batch dim (level_units = 2)
    for l1, l2 in zip(jax.tree.leaves(b1), jax.tree.leaves(b2)):
        assert l1.dtype == l2.dtype
        assert l2.shape[0] == 2 * l1.shape[0]
        assert l1.shape[1:] == l2.shape[1:]
