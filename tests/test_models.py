"""Per-architecture smoke tests (reduced configs) + layer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or offline fallback

from repro.configs import ARCH_IDS, SHAPES, get_config, reduced
from repro.models import decode_step, init_cache, init_params, loss_fn, prefill
from repro.models.layers import chunked_attention, decode_attention, rms_norm
from repro.models.moe import moe_ffn
from repro.models.ssm import selective_scan, selective_step
from repro.models.transformer import forward


def _batch(cfg, key, B=2, S=16):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    if cfg.family == "audio":
        batch["extra"] = {"frames": 0.1 * jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model))}
    if cfg.family == "vlm":
        batch["extra"] = {"patches": 0.1 * jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.d_model))}
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced variant (<=2-group depth, d<=512, <=4 experts): one forward +
    one SGD step on CPU; asserts shapes and finiteness."""
    cfg = reduced(get_config(arch))
    assert cfg.d_model <= 512 and (not cfg.is_moe or cfg.n_experts <= 4)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = forward(params, batch["tokens"], cfg, extra=batch.get("extra"))
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, g = jax.value_and_grad(loss_fn)(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    p2 = jax.tree.map(lambda p, gg: p - 0.01 * gg, params, g)
    l2 = loss_fn(p2, batch, cfg)
    assert bool(jnp.isfinite(l2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_matches_forward(arch):
    """prefill + decode_step reproduces the full-forward logits exactly."""
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 9
    batch = _batch(cfg, key, B=B, S=S + 1)
    toks = batch["tokens"]
    full, _ = forward(params, toks, cfg, extra=batch.get("extra"), remat=False)
    _, cache = prefill(params, toks[:, :S], cfg, extra=batch.get("extra"),
                       pad_to=S + 4)
    got, _ = decode_step(params, cache, toks[:, S], jnp.int32(S), cfg)
    want = full[:, S]
    rel = float(jnp.max(jnp.abs(want - got))) / (float(jnp.max(jnp.abs(want))) + 1e-9)
    assert rel < 5e-3, rel


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_init_cache_structure(arch):
    cfg = reduced(get_config(arch)).for_shape(SHAPES["decode_32k"])
    cache = init_cache(cfg, 2, 64)
    for leaf in jax.tree.leaves(cache):
        assert leaf.shape[0] == cfg.n_groups


# ---------------------------------------------------------------- attention


def _naive_attention(q, k, v, causal=True, window=0):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qh = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qh, k) / (hd ** 0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 2), st.sampled_from([(4, 2), (6, 3), (8, 8)]),
       st.sampled_from([7, 16, 33]), st.booleans(), st.sampled_from([0, 8]))
def test_chunked_attention_matches_naive(B, heads, S, causal, window):
    H, KV = heads
    hd = 8
    key = jax.random.PRNGKey(B * 1000 + S)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    got = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=8, kv_chunk=8)
    want = _naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_naive_last_row():
    key = jax.random.PRNGKey(7)
    B, S, H, KV, hd = 2, 12, 6, 3, 8
    q = jax.random.normal(key, (B, 1, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    got = decode_attention(q, k, v)
    qf = jnp.broadcast_to(q, (B, 1, H, hd))
    want = _naive_attention(qf, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- mamba


def test_selective_scan_matches_stepwise():
    """Chunked associative scan == sequential single-step recurrence."""
    key = jax.random.PRNGKey(3)
    Bt, L, di, ds = 2, 13, 4, 3
    x = jax.random.normal(key, (Bt, L, di))
    delta = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (Bt, L, di)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (di, ds)))
    B = jax.random.normal(jax.random.fold_in(key, 3), (Bt, L, ds))
    C = jax.random.normal(jax.random.fold_in(key, 4), (Bt, L, ds))
    D = jnp.ones((di,))
    y, h = selective_scan(x, delta, A, B, C, D, chunk=4)
    hs = jnp.zeros((Bt, di, ds))
    ys = []
    for t in range(L):
        yt, hs = selective_step(x[:, t], delta[:, t], A, B[:, t], C[:, t], D, hs)
        ys.append(yt)
    want = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hs), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- MoE


def test_moe_capacity_and_combine_weights():
    key = jax.random.PRNGKey(5)
    B, S, D, E, F = 2, 8, 16, 4, 32
    x = jax.random.normal(key, (B, S, D))
    p = {
        "router": jax.random.normal(jax.random.fold_in(key, 1), (D, E)),
        "we1": jax.random.normal(jax.random.fold_in(key, 2), (E, D, F)) * 0.1,
        "we3": jax.random.normal(jax.random.fold_in(key, 3), (E, D, F)) * 0.1,
        "we2": jax.random.normal(jax.random.fold_in(key, 4), (E, F, D)) * 0.1,
    }
    out, aux = moe_ffn(x, p, top_k=2, capacity_factor=2.0)
    assert out.shape == (B, S, D)
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(aux))
    assert float(aux) > 0.5  # load-balance loss ~ E * sum(p_e * f_e) >= 1 at balance


def test_moe_dropped_tokens_with_tiny_capacity():
    """capacity_factor -> tiny: most tokens dropped, output ~ 0 for them."""
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (1, 32, 8))
    p = {
        "router": jnp.zeros((8, 2)).at[0, 0].set(10.0),  # everyone -> expert 0
        "we1": jnp.ones((2, 8, 4)) * 0.1,
        "we3": jnp.ones((2, 8, 4)) * 0.1,
        "we2": jnp.ones((2, 4, 8)) * 0.1,
    }
    out, _ = moe_ffn(x, p, top_k=1, capacity_factor=0.1)
    # capacity = 32*1*0.1/2 = 1 -> at most 1 token per expert served
    nz = jnp.sum(jnp.any(jnp.abs(out[0]) > 1e-7, axis=-1))
    assert int(nz) <= 2


# ---------------------------------------------------------------- misc


def test_rms_norm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 10
    y = rms_norm(x, jnp.ones(64))
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-2)


def test_sliding_window_config_swap():
    cfg = get_config("qwen2.5-32b")
    assert cfg.sliding_window == 0
    c2 = cfg.for_shape(SHAPES["long_500k"])
    assert c2.sliding_window == 8192
    assert cfg.for_shape(SHAPES["decode_32k"]).sliding_window == 0


def test_whisper_skips_long500k():
    cfg = get_config("whisper-base")
    assert not cfg.supports_shape(SHAPES["long_500k"])
    assert cfg.supports_shape(SHAPES["decode_32k"])


def test_param_counts_match_names():
    for arch, lo, hi in [("jamba-1.5-large-398b", 380e9, 410e9),
                         ("arctic-480b", 460e9, 500e9),
                         ("qwen2.5-32b", 30e9, 35e9),
                         ("smollm-360m", 0.3e9, 0.5e9),
                         ("rwkv6-1.6b", 1.3e9, 1.8e9)]:
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


# ---------------------------------------------------------------- flash


def test_flash_attention_matches_chunked_oracle():
    """flash custom-VJP (fwd+bwd) vs the pure scan oracle across GQA shapes."""
    from repro.models.flash import flash_attention
    key = jax.random.PRNGKey(11)
    for (B, S, H, KV, hd, causal, window) in [
            (2, 33, 6, 3, 8, True, 0), (1, 16, 4, 4, 8, False, 0),
            (2, 40, 8, 2, 16, True, 8), (1, 64, 2, 1, 4, True, 0)]:
        q = jax.random.normal(key, (B, S, H, hd))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
        def f(q, k, v):
            return flash_attention(q, k, v, causal, window, 0, 8, "")

        def r(q, k, v):
            return chunked_attention(q, k, v, causal=causal, window=window,
                                     q_chunk=8, kv_chunk=8)
        np.testing.assert_allclose(np.asarray(f(q, k, v)), np.asarray(r(q, k, v)),
                                   rtol=2e-4, atol=2e-4)
        gf = jax.grad(lambda *a: jnp.sum(jnp.sin(f(*a))), argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(lambda *a: jnp.sum(jnp.sin(r(*a))), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gf, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)


def test_selective_scan_remat_grads_match_oracle():
    """Mamba remat (§Perf it.4) must not change gradients."""
    key = jax.random.PRNGKey(4)
    Bt, L, di, ds = 1, 11, 3, 2
    x = jax.random.normal(key, (Bt, L, di))
    delta = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (Bt, L, di)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (di, ds)))
    B = jax.random.normal(jax.random.fold_in(key, 3), (Bt, L, ds))
    C = jax.random.normal(jax.random.fold_in(key, 4), (Bt, L, ds))
    D = jnp.ones((di,))

    def loss_scan(x):
        y, _ = selective_scan(x, delta, A, B, C, D, chunk=4)
        return jnp.sum(jnp.tanh(y))

    def loss_steps(x):
        hs = jnp.zeros((Bt, di, ds))
        tot = 0.0
        for t in range(L):
            yt, hs = selective_step(x[:, t], delta[:, t], A, B[:, t], C[:, t], D, hs)
            tot = tot + jnp.sum(jnp.tanh(yt))
        return tot

    g1 = jax.grad(loss_scan)(x)
    g2 = jax.grad(loss_steps)(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("arch", ["smollm-360m", "rwkv6-1.6b", "jamba-1.5-large-398b"])
def test_greedy_generation_matches_full_forward(arch):
    """Multi-step decode: greedy generation with the cache must equal greedy
    generation by repeated full forwards (end-to-end serving correctness)."""
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(9)
    params = init_params(cfg, key)
    B, S, n_new = 2, 7, 4
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    # reference: repeated full forward
    ref = toks
    for _ in range(n_new):
        logits, _ = forward(params, ref, cfg, remat=False)
        ref = jnp.concatenate([ref, jnp.argmax(logits[:, -1:], -1)], axis=1)

    # cached path
    logits, cache = prefill(params, toks, cfg, pad_to=S + n_new + 1)
    cur = jnp.argmax(logits, -1)
    got = [cur]
    for i in range(n_new - 1):
        logits, cache = decode_step(params, cache, cur, jnp.int32(S + i), cfg)
        cur = jnp.argmax(logits, -1)
        got.append(cur)
    got = jnp.stack(got, 1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref[:, S:]))
