"""Mode B (sharded robust training) correctness — runs in subprocesses with 8
placeholder devices so the main pytest process keeps seeing 1 CPU device."""
import os
import subprocess
import sys
import textwrap

import pytest

# every test here spawns an 8-device subprocess that recompiles Mode B from
# scratch — CI runs them in the dedicated slow-parity job, not the tier-1 lane
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.configs.base import ShapeConfig
        from repro.launch.steps import build_train_step
        from repro.launch.mesh import set_mesh
        from repro.models import init_params, loss_fn
        from repro.core.aggregators import get_aggregator
    """ % SRC) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-4000:] + "\n" + r.stderr[-4000:]
    return r.stdout


def test_modeb_mean_no_attack_equals_plain_dp():
    """With Mean + no attack, the robust all-to-all reduction must be
    numerically identical to ordinary data-parallel training."""
    _run("""
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = reduced(get_config("smollm-360m"))
        shape = ShapeConfig("t", 32, 8, "train")
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        g = jax.grad(loss_fn)(params, batch, cfg)
        p_ref = jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)
        bs = build_train_step(cfg, mesh, shape, aggregator="mean", attack="none",
                              lr=0.1, dtype=jnp.float32)
        with set_mesh(mesh):
            p2, _, loss = bs.fn(params, (), batch, jnp.zeros((4,), jnp.float32))
        errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p2, p_ref)
        err = max(jax.tree.leaves(errs))
        assert err < 1e-4, err
        print("OK", err)
    """)


def test_modeb_cwmed_matches_modea_aggregation():
    """The sharded per-block CWMed equals the global CWMed (coordinate-wise
    rules are exact under sharding): Mode B grads == CWMed of per-worker
    grads computed independently."""
    _run("""
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = reduced(get_config("qwen3-0.6b"))
        shape = ShapeConfig("t", 16, 8, "train")
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        # Mode A: per-worker grads (batch split 4 ways), CWMed.tree
        bw = 2
        gs = [jax.grad(loss_fn)(params,
              {"tokens": toks[i*bw:(i+1)*bw], "labels": jnp.roll(toks[i*bw:(i+1)*bw], -1, 1)},
              cfg) for i in range(4)]
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *gs)
        agg = get_aggregator("cwmed").tree(stacked)
        p_ref = jax.tree.map(lambda p, gg: p - 0.05 * gg.astype(jnp.float32), params, agg)
        bs = build_train_step(cfg, mesh, shape, aggregator="cwmed", attack="none",
                              lr=0.05, dtype=jnp.float32)
        with set_mesh(mesh):
            p2, _, _ = bs.fn(params, (), batch, jnp.zeros((4,), jnp.float32))
        errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p2, p_ref)
        err = max(jax.tree.leaves(errs))
        assert err < 1e-4, err
        print("OK", err)
    """)


def test_modeb_signflip_byzantine_is_neutralized():
    """One sign-flipping worker of four: CWTM step must stay a descent-ish
    update (params finite, loss decreases over a few steps)."""
    _run("""
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = reduced(get_config("smollm-360m"))
        shape = ShapeConfig("t", 32, 8, "train")
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        bs = build_train_step(cfg, mesh, shape, aggregator="cwtm",
                              attack="sign_flip", lr=0.05, dtype=jnp.float32)
        maskf = jnp.array([1., 0., 0., 0.])
        opt_state = ()
        losses = []
        batches = []
        for t in range(8):
            toks = jax.random.randint(jax.random.PRNGKey(t), (8, 32), 0, cfg.vocab_size)
            batches.append({"tokens": toks, "labels": jnp.roll(toks, -1, 1)})
        with set_mesh(mesh):
            for t in range(8):
                batch = jax.tree.map(lambda x, s: jax.device_put(x, s.sharding),
                                     batches[t], bs.inputs[2])
                params, opt_state, loss = bs.fn(params, opt_state, batch, maskf)
                losses.append(float(loss))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses
        print("OK", losses[0], "->", losses[-1])
    """)


def test_modeb_multipod_axes():
    """Worker axes = (pod, data): m=4 workers across 2 pods lower and run."""
    _run("""
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = reduced(get_config("qwen2-moe-a2.7b"))
        shape = ShapeConfig("t", 16, 8, "train")
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        bs = build_train_step(cfg, mesh, shape, aggregator="cwmed",
                              attack="ipm", lr=0.05, dtype=jnp.float32)
        with set_mesh(mesh):
            p2, _, loss = bs.fn(params, (), batch, jnp.array([1., 0., 0., 0.]))
        assert np.isfinite(float(loss))
        assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(p2))
        print("OK", float(loss))
    """)


def test_modeb_mlmc_level_step_matches_manual_algorithm2():
    """Mode-B MLMC step at level J=1 == hand-computed Algorithm 2 round:
    ĝ⁰/ĝ⁰_... from nested batch slices, CWMed aggregation, fail-safe check,
    g = ĝ⁰ + 2(ĝ¹ − ĝ⁰'), SGD update."""
    _run("""
        from repro.launch.steps import build_mlmc_train_step
        from repro.core.mlmc import MLMCConfig
        from repro.core.aggregators import get_aggregator
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = reduced(get_config("qwen3-0.6b"))
        shape = ShapeConfig("t", 16, 8, "train")   # B=8 per level-unit
        mc = MLMCConfig(T=64, m=4, V=1e9)          # huge V: fail-safe passes
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (16, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        # manual Algorithm 2, J=1: per worker, unit batches of 2 rows
        agg = get_aggregator("cwmed")
        def worker_grad(rows):
            b = {"tokens": rows, "labels": jnp.roll(rows, -1, 1)}
            return jax.grad(loss_fn)(params, b, cfg)
        # worker i holds rows [i*4:(i+1)*4] of the level-1 batch (16 rows);
        # level-0 slice = first 2 rows per worker; level-1 = all 4
        g0s, g1s = [], []
        for i in range(4):
            rows = toks[i*4:(i+1)*4]
            g0s.append(worker_grad(rows[:2]))
            g1s.append(worker_grad(rows))
        g0 = agg.tree(jax.tree.map(lambda *l: jnp.stack(l), *g0s))
        g1 = agg.tree(jax.tree.map(lambda *l: jnp.stack(l), *g1s))
        g = jax.tree.map(lambda a, b, c: a + 2.0 * (c.astype(jnp.float32)
                         - b.astype(jnp.float32)), g0, g0, g1)
        # NOTE: ĝ^{J-1} in Alg 2 reuses the FIRST half of the same samples —
        # which is exactly the g0 slice here, so diff = ĝ¹ − ĝ⁰.
        p_ref = jax.tree.map(lambda p, gg: p - 0.05 * gg.astype(jnp.float32), params, g)
        bs = build_mlmc_train_step(cfg, mesh, shape, mc, 1, aggregator="cwmed",
                                   attack="none", lr=0.05, dtype=jnp.float32)
        with set_mesh(mesh):
            batch_p = jax.tree.map(lambda x, s: jax.device_put(x, s.sharding),
                                   batch, bs.inputs[2])
            p2, _, (ok, dn) = bs.fn(params, (), batch_p, jnp.zeros((4,), jnp.float32))
        assert float(ok) == 1.0
        errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p2, p_ref)
        err = max(jax.tree.leaves(errs))
        assert err < 2e-4, err
        print("OK modeB mlmc == manual Alg2:", err)
    """)
