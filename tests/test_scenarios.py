"""``core.scenarios`` edge cases + the vmapped sweep contract (DESIGN.md §7).

The vmapped matrix (``driver="vmap"``) must be a drop-in for the per-cell
compiled loop: same tidy rows, in input order, equal numerics lane for lane.
Both paths run the identical scan body — vmap only adds a lane axis to the
masks and the model state — but batching may reorder float ops at ULP level
(XLA fuses the batched body differently), so float fields are locked to the
parity suite's 1e-6 tolerance while the integer round logs (levels,
fail-safe trips, costs) must match exactly.
"""
import warnings

import numpy as np
import pytest

from repro.core.robust_train import run_dynabro_scan, run_dynabro_scan_sweep
from repro.core.scenarios import (
    Scenario, format_table, make_quadratic_task, run_matrix,
    run_matrix_vmapped, scenario_grid,
)
from repro.core.switching import get_switcher

TASK = make_quadratic_task()
M = 9


def test_empty_grid():
    assert scenario_grid([], [], []) == []
    assert run_matrix(TASK, [], m=M, T=10, V=3.0) == []
    assert run_matrix(TASK, [], m=M, T=10, V=3.0, driver="vmap") == []


def test_single_cell_grid():
    grid = scenario_grid(["sign_flip"], [("static", {"n_byz": 3})], ["cwmed"])
    assert len(grid) == 1
    assert grid[0].name == "sign_flip|static(n_byz=3)|cwmed"
    [row_v] = run_matrix(TASK, grid, m=M, T=24, V=3.0, driver="vmap")
    [row_s] = run_matrix(TASK, grid, m=M, T=24, V=3.0, driver="scan")
    assert row_v["driver"] == "vmap" and row_s["driver"] == "scan"
    np.testing.assert_allclose(row_v["final"], row_s["final"], rtol=1e-6,
                               atol=1e-7)
    assert row_v["cost"] == row_s["cost"]
    assert row_v["failsafe_trips"] == row_s["failsafe_trips"]


def test_duplicate_scenario_names():
    """Duplicate cells are legal: they become duplicate lanes/rows with equal
    results, and format_table keeps one column/line per distinct key."""
    sc = Scenario("sign_flip", "static", "cwmed",
                  switcher_kwargs=(("n_byz", 3),))
    rows = run_matrix(TASK, [sc, sc], m=M, T=24, V=3.0, driver="vmap")
    assert len(rows) == 2
    assert rows[0]["final"] == rows[1]["final"]
    assert rows[0]["cost"] == rows[1]["cost"]
    table = format_table(rows)
    assert table.count("cwmed") == 1


@pytest.mark.parametrize("use_mlmc", [True, False])
def test_vmapped_matrix_equals_looped_matrix(use_mlmc):
    """Row-for-row equality of the vmapped sweep against the per-cell loop
    across a grid mixing attacks, switchers (the vmapped lane axis) and
    aggregators (incl. MFM's option-2 config)."""
    grid = scenario_grid(
        ["sign_flip", ("ipm", {"eps": 0.3})],
        [("periodic", {"n_byz": 3, "K": 5}), ("static", {"n_byz": 3}),
         ("bernoulli", {"p": 0.1, "D": 5, "delta_max": 0.5})],
        ["cwmed", "mfm"])
    assert len(grid) == 12
    kw = dict(m=M, T=32, V=3.0, delta=3 / M + 0.01, j_cap=3,
              use_mlmc=use_mlmc, seed=2)
    rows_v = run_matrix(TASK, grid, driver="vmap", **kw)
    rows_s = run_matrix(TASK, grid, driver="scan", **kw)
    assert [r["switcher"] for r in rows_v] == [r["switcher"] for r in rows_s]
    for rv, rs in zip(rows_v, rows_s):
        np.testing.assert_allclose(rv["final"], rs["final"], rtol=1e-6,
                                   atol=1e-7, err_msg=str((rv, rs)))
        assert rv["failsafe_trips"] == rs["failsafe_trips"]
        assert rv["mean_level"] == rs["mean_level"]
        assert rv["cost"] == rs["cost"]


def test_vmapped_chunking_is_invisible():
    grid = scenario_grid(["sign_flip"],
                         [("periodic", {"n_byz": 3, "K": 5}),
                          ("static", {"n_byz": 3})], ["cwmed"])
    r0 = run_matrix_vmapped(TASK, grid, m=M, T=32, V=3.0)
    r16 = run_matrix_vmapped(TASK, grid, m=M, T=32, V=3.0, chunk=16)
    for a, b in zip(r0, r16):
        assert a["final"] == b["final"]


def _cfg_for(attack, T=32, j_cap=3, agg="cwmed"):
    from repro.core.mlmc import MLMCConfig
    from repro.core.robust_train import DynaBROConfig

    name, kw = (attack, {}) if isinstance(attack, str) else attack
    return DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=M, V=3.0, kappa=1.0, j_cap=j_cap,
                        option=2 if agg == "mfm" else 1),
        aggregator=agg, delta=0.45, attack=name,
        attack_kwargs=dict(kw) or None)


def test_attack_lane_sweep_matches_per_cell_scan_exactly():
    """The tentpole contract: lanes mixing sign_flip / ipm(eps) / alie / none
    in one vmapped call match per-cell ``run_dynabro_scan`` lane for lane —
    exact round logs (incl. beyond-cap costs: j_cap=3, T=32 samples J=4
    w.p. 1/8 per round), finals within the parity tolerance."""
    from repro.optim.optimizers import sgd

    specs = ["sign_flip", ("ipm", {"eps": 0.3}), "alie", "none"]
    kss = (5, 8, 13, 20)
    lanes = [(a, K) for a in specs for K in kss]
    sampler = TASK.make_sampler(M)
    switchers = [get_switcher("periodic", M, n_byz=3, K=K, seed=1)
                 for _, K in lanes]
    outs = run_dynabro_scan_sweep(
        TASK.grad_fn, TASK.params0, sgd(2e-2), _cfg_for("sign_flip"),
        switchers, sampler, 32, seed=1, attacks=[a for a, _ in lanes])
    assert len(outs) == len(lanes) == 16
    saw_beyond_cap = False
    for (attack, K), (p, logs) in zip(lanes, outs):
        ref_p, ref_logs, _ = run_dynabro_scan(
            TASK.grad_fn, TASK.params0, sgd(2e-2), _cfg_for(attack),
            get_switcher("periodic", M, n_byz=3, K=K, seed=1), sampler, 32,
            seed=1)
        assert logs == ref_logs, f"lane {attack} K={K}"
        saw_beyond_cap |= any(l.level > 3 for l in logs)
        np.testing.assert_allclose(np.asarray(p["x"]), np.asarray(ref_p["x"]),
                                   rtol=1e-6, atol=1e-7,
                                   err_msg=f"lane {attack} K={K}")
    assert saw_beyond_cap  # the exact-log check covered beyond-cap costs


def test_vmapped_matrix_single_dispatch_whole_grid(monkeypatch):
    """The tentpole contract: a 4-attack × 4-switcher × 4-aggregator grid
    runs as ONE sweep call for the WHOLE grid — every cell a lane, the
    aggregator axis dispatched per lane like the attack axis (not one call
    per aggregator group)."""
    from repro.api.session import Session

    lane_counts = []
    orig = Session.sweep
    depth = [0]

    def counting(self, spec, *args, **kw):
        # grouping/chunking recurse through sweep(); the contract is about
        # the driver's TOP-LEVEL calls — one for the whole grid
        if depth[0] == 0:
            lane_counts.append(spec.lanes)
        depth[0] += 1
        try:
            return orig(self, spec, *args, **kw)
        finally:
            depth[0] -= 1

    monkeypatch.setattr(Session, "sweep", counting)
    grid = scenario_grid(
        ["sign_flip", ("ipm", {"eps": 0.3}), "alie", "none"],
        [("periodic", {"n_byz": 3, "K": K}) for K in (5, 8, 13, 20)],
        ["cwmed", ("cwtm", {"delta": 0.4}), "krum", "mfm"])
    rows = run_matrix(TASK, grid, m=M, T=16, V=3.0, j_cap=2, driver="vmap")
    assert lane_counts == [64]
    assert all(np.isfinite(r["final"]) for r in rows)


def test_agg_lane_sweep_matches_per_cell_scan_exactly():
    """Aggregator-axis analog of the attack-lane contract: a 16-lane grid
    mixing aggregation rules (incl. MFM, whose Option-2 fail-safe constant
    differs, an nnm+ composite, and CWTM at two deltas — the traced
    hyperparameter axis) with mixed attacks matches per-cell
    ``run_dynabro_scan`` lane for lane — exact round logs, finals within
    the parity tolerance."""
    import dataclasses

    from repro.optim.optimizers import sgd

    aggs = [("cwmed", {}), ("cwtm", {"delta": 0.45}), ("cwtm", {"delta": 0.2}),
            ("mfm", {}), ("krum", {"delta": 0.3}), ("nnm+cwmed", {"delta": 0.3}),
            ("geomed", {"iters": 6}), ("cwtm", {"delta": 0.45})]
    attacks = ["sign_flip", ("ipm", {"eps": 0.3})]
    lanes = [(a, g) for a in attacks for g in aggs]
    sampler = TASK.make_sampler(M)
    switchers = [get_switcher("periodic", M, n_byz=3, K=7, seed=1)
                 for _ in lanes]
    outs = run_dynabro_scan_sweep(
        TASK.grad_fn, TASK.params0, sgd(2e-2), _cfg_for("sign_flip"),
        switchers, sampler, 32, seed=1, attacks=[a for a, _ in lanes],
        aggregators=[g for _, g in lanes])
    assert len(outs) == len(lanes) == 16
    for (attack, (gname, gkw)), (p, logs) in zip(lanes, outs):
        cfg = _cfg_for(attack, agg=gname)
        cfg = dataclasses.replace(
            cfg, delta=gkw.get("delta", cfg.delta),
            aggregator_kwargs=dict(gkw) or None)
        ref_p, ref_logs, _ = run_dynabro_scan(
            TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
            get_switcher("periodic", M, n_byz=3, K=7, seed=1), sampler, 32,
            seed=1)
        assert logs == ref_logs, f"lane {attack} {gname}{gkw}"
        np.testing.assert_allclose(np.asarray(p["x"]), np.asarray(ref_p["x"]),
                                   rtol=1e-6, atol=1e-7,
                                   err_msg=f"lane {attack} {gname}{gkw}")


def test_grouped_sweep_shuffled_lanes_caller_order_and_dispatch(monkeypatch):
    """Branch-homogeneous grouping contract (DESIGN.md §7): a SHUFFLED
    16-lane mixed-rule grid (4 rules interleaved across attacks and
    switching periods) returns rows in the CALLER's lane order — grouping
    permutes lanes into per-rule sub-sweeps and must un-permute — while
    building exactly one single-rule scan_fn per distinct rule, in
    first-appearance order of the shuffled grid. Results still match
    per-cell ``run_dynabro_scan`` exactly (extends the agg-lane parity
    test above to permuted mixed grids)."""
    import dataclasses

    import repro.core.robust_train as rt
    from repro.optim.optimizers import sgd

    aggs = [("cwmed", {}), ("cwtm", {"delta": 0.45}), ("mfm", {}),
            ("nnm+cwmed", {"delta": 0.3})]
    lanes = [(a, g, K) for a in ["sign_flip", ("ipm", {"eps": 0.3})]
             for g in aggs for K in (5, 9)]
    order = np.random.default_rng(7).permutation(len(lanes))
    lanes = [lanes[i] for i in order]  # interleaves the rules across lanes
    first_seen = tuple(dict.fromkeys(g[0] for _, g, _ in lanes))
    assert first_seen != tuple(g[0] for g in aggs)  # shuffle did something

    built = []
    orig = rt.make_dynabro_scan_fn

    def recording(*args, **kw):
        if kw.get("lane_aggregators") is not None:
            built.append(kw["lane_aggregators"])
        return orig(*args, **kw)

    monkeypatch.setattr(rt, "make_dynabro_scan_fn", recording)
    sampler = TASK.make_sampler(M)
    outs = run_dynabro_scan_sweep(
        TASK.grad_fn, TASK.params0, sgd(2e-2), _cfg_for("sign_flip", T=16),
        [get_switcher("periodic", M, n_byz=3, K=K, seed=1)
         for _, _, K in lanes],
        sampler, 16, seed=1, attacks=[a for a, _, _ in lanes],
        aggregators=[g for _, g, _ in lanes])
    monkeypatch.setattr(rt, "make_dynabro_scan_fn", orig)
    # one branch-homogeneous dispatch per distinct rule, caller's order
    assert built == [(name,) for name in first_seen]
    assert len(outs) == 16
    for (attack, (gname, gkw), K), (p, logs) in zip(lanes, outs):
        cfg = _cfg_for(attack, T=16, agg=gname)
        cfg = dataclasses.replace(
            cfg, delta=gkw.get("delta", cfg.delta),
            aggregator_kwargs=dict(gkw) or None)
        ref_p, ref_logs, _ = run_dynabro_scan(
            TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
            get_switcher("periodic", M, n_byz=3, K=K, seed=1), sampler, 16,
            seed=1)
        assert logs == ref_logs, f"lane {attack} {gname}{gkw} K={K}"
        np.testing.assert_allclose(np.asarray(p["x"]), np.asarray(ref_p["x"]),
                                   rtol=1e-6, atol=1e-7,
                                   err_msg=f"lane {attack} {gname}{gkw} K={K}")


def test_grouped_sweep_scan_fn_mapping_validation():
    """The {rule_name: scan_fn} steady-state form: keys must cover the
    grid's distinct rules (a superset is fine — lane_chunk sub-sweeps see
    rule subsets), and a mapping without aggregators is an error."""
    from repro.core.robust_train import make_dynabro_scan_fn
    from repro.optim.optimizers import sgd

    cfg = _cfg_for("sign_flip", T=8, j_cap=1)
    sws = [get_switcher("static", M, n_byz=2) for _ in range(2)]
    fns = {name: make_dynabro_scan_fn(TASK.grad_fn, cfg, sgd(2e-2),
                                      lane_aggregators=(name,))
           for name in ("cwmed", "cwtm")}
    with pytest.raises(ValueError, match="do not cover"):
        run_dynabro_scan_sweep(
            TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, sws,
            TASK.make_sampler(M), 8, scan_fn={"cwmed": fns["cwmed"]},
            aggregators=["cwmed", "cwtm"])
    with pytest.raises(ValueError, match="no aggregators"):
        run_dynabro_scan_sweep(
            TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, sws,
            TASK.make_sampler(M), 8, scan_fn=fns)
    # a well-formed mapping runs grouped and matches scan_fn=None lane-wise
    outs = run_dynabro_scan_sweep(
        TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, sws,
        TASK.make_sampler(M), 8, scan_fn=fns,
        aggregators=["cwmed", ("cwtm", {"delta": 0.45})])
    ref = run_dynabro_scan_sweep(
        TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, sws,
        TASK.make_sampler(M), 8,
        aggregators=["cwmed", ("cwtm", {"delta": 0.45})])
    for (p, logs), (rp, rlogs) in zip(outs, ref):
        assert logs == rlogs
        np.testing.assert_allclose(np.asarray(p["x"]), np.asarray(rp["x"]),
                                   rtol=1e-6, atol=1e-7)


def test_agg_hyperparameter_axis_free_lanes():
    """Grids varying ONLY an aggregator hyperparameter (CWTM at three δ) are
    lanes of one dispatch, produce distinct results, and keep their own
    pivot lines via aggregator_label."""
    grid = scenario_grid(
        ["sign_flip"], [("static", {"n_byz": 3})],
        [("cwtm", {"delta": d}) for d in (0.1, 0.25, 0.4)])
    rows = run_matrix(TASK, grid, m=M, T=24, V=3.0, j_cap=2, driver="vmap")
    assert len({r["aggregator_label"] for r in rows}) == 3
    assert len({r["final"] for r in rows}) > 1  # the deltas actually matter
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        table = format_table(rows, row_key="aggregator")
    assert "cwtm(delta=0.1)" in table and "cwtm(delta=0.4)" in table
    # and each lane matches its per-cell scan run
    for sc, rv in zip(grid, rows):
        rs = run_matrix(TASK, [sc], m=M, T=24, V=3.0, j_cap=2,
                        driver="scan")[0]
        np.testing.assert_allclose(rv["final"], rs["final"], rtol=1e-6,
                                   atol=1e-7)
        assert rv["cost"] == rs["cost"]
        assert rv["failsafe_trips"] == rs["failsafe_trips"]


def test_sweep_rejects_mismatched_agg_lane_scan_fn():
    """Prebuilt-scan_fn validation on the aggregator lane axis, both
    directions (mirrors the attack-axis checks)."""
    from repro.core.robust_train import make_dynabro_scan_fn
    from repro.optim.optimizers import sgd

    cfg = _cfg_for("sign_flip", T=8, j_cap=1)
    sws = [get_switcher("static", M, n_byz=2) for _ in range(2)]
    wrong = make_dynabro_scan_fn(TASK.grad_fn, cfg, sgd(2e-2),
                                 lane_aggregators=("cwtm", "cwmed"))
    with pytest.raises(ValueError, match="lane_aggregators"):
        run_dynabro_scan_sweep(
            TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, sws,
            TASK.make_sampler(M), 8, scan_fn=wrong,
            aggregators=["cwmed", "cwtm"])
    # lane-built scan_fn but no aggregators passed
    with pytest.raises(ValueError, match="no\\s+aggregators"):
        run_dynabro_scan_sweep(
            TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, sws,
            TASK.make_sampler(M), 8, scan_fn=wrong)
    # plain scan_fn but aggregators passed
    plain = make_dynabro_scan_fn(TASK.grad_fn, cfg, sgd(2e-2))
    with pytest.raises(ValueError, match="lane_aggregators"):
        run_dynabro_scan_sweep(
            TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, sws,
            TASK.make_sampler(M), 8, scan_fn=plain,
            aggregators=["cwmed", "cwtm"])
    # and the per-cell driver rejects an aggregator-lane-built fn
    with pytest.raises(ValueError, match="run_dynabro_scan_sweep"):
        run_dynabro_scan(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, sws[0],
                         TASK.make_sampler(M), 8, scan_fn=wrong)


def test_format_table_kwarg_columns_not_collapsed():
    """Cells differing only in attack kwargs keep their own pivot columns
    (and produce no collision warning)."""
    grid = scenario_grid([("ipm", {"eps": 0.1}), ("ipm", {"eps": 0.9})],
                         [("static", {"n_byz": 3})], ["cwmed"])
    rows = run_matrix(TASK, grid, m=M, T=16, V=3.0, j_cap=2, driver="vmap")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        table = format_table(rows)
    assert "ipm(eps=0.1)" in table and "ipm(eps=0.9)" in table
    assert rows[0]["attack_label"] != rows[1]["attack_label"]
    assert rows[0]["attack"] == rows[1]["attack"] == "ipm"


def test_format_table_duplicate_nan_rows_stay_silent():
    """Duplicate lanes of a diverged scenario (both NaN) are duplicates,
    not a collision."""
    rows = [{"aggregator": "mean", "attack": "ipm", "final": float("nan")},
            {"aggregator": "mean", "attack": "ipm", "final": float("nan")}]
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        format_table(rows)


def test_sweep_rejects_mismatched_lane_scan_fn():
    """A caller-prebuilt scan_fn whose lax.switch branch order differs from
    the ids this sweep derives would silently apply the wrong attack per
    lane — it must be rejected loudly."""
    from repro.core.robust_train import make_dynabro_scan_fn
    from repro.optim.optimizers import sgd

    cfg = _cfg_for("sign_flip", T=8, j_cap=1)
    wrong = make_dynabro_scan_fn(TASK.grad_fn, cfg, sgd(2e-2),
                                 lane_attacks=("ipm", "sign_flip"))
    with pytest.raises(ValueError, match="lane_attacks"):
        run_dynabro_scan_sweep(
            TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
            [get_switcher("static", M, n_byz=2) for _ in range(2)],
            TASK.make_sampler(M), 8, scan_fn=wrong,
            attacks=["sign_flip", "ipm"])
    # and the reverse direction: a lane-built scan_fn without attacks
    with pytest.raises(ValueError, match="no\\s+attacks"):
        run_dynabro_scan_sweep(
            TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
            [get_switcher("static", M, n_byz=2) for _ in range(2)],
            TASK.make_sampler(M), 8, scan_fn=wrong)


def test_run_scenario_driver_validation_and_vmap_route():
    """Unknown driver strings raise instead of silently running legacy;
    driver='vmap' on a single cell routes through the sweep and matches
    the scan driver."""
    from repro.core.scenarios import run_scenario

    sc = Scenario("sign_flip", "static", "cwmed",
                  switcher_kwargs=(("n_byz", 3),))
    with pytest.raises(ValueError, match="unknown driver"):
        run_scenario(TASK, sc, m=M, T=8, V=3.0, driver="lgacy")
    row_v = run_scenario(TASK, sc, m=M, T=16, V=3.0, j_cap=2, driver="vmap")
    row_s = run_scenario(TASK, sc, m=M, T=16, V=3.0, j_cap=2, driver="scan")
    assert row_v["driver"] == "vmap"
    np.testing.assert_allclose(row_v["final"], row_s["final"], rtol=1e-6,
                               atol=1e-7)
    assert row_v["cost"] == row_s["cost"]


def test_scan_driver_rejects_lane_built_scan_fn():
    from repro.core.robust_train import make_dynabro_scan_fn
    from repro.optim.optimizers import sgd

    cfg = _cfg_for("sign_flip", T=8, j_cap=1)
    lane_fn = make_dynabro_scan_fn(TASK.grad_fn, cfg, sgd(2e-2),
                                   lane_attacks=("sign_flip",))
    with pytest.raises(ValueError, match="run_dynabro_scan_sweep"):
        run_dynabro_scan(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
                         get_switcher("static", M, n_byz=2),
                         TASK.make_sampler(M), 8, scan_fn=lane_fn)


def test_scan_driver_rejects_mesh_mismatched_scan_fn():
    """An unsharded prebuilt scan_fn passed with mesh= would silently run
    the whole loop unsharded; both mismatch directions must fail loudly."""
    from repro.core.robust_train import make_dynabro_scan_fn
    from repro.launch.mesh import make_worker_mesh
    from repro.optim.optimizers import sgd

    cfg = _cfg_for("sign_flip", T=8, j_cap=1)
    mesh = make_worker_mesh(1)
    plain_fn = make_dynabro_scan_fn(TASK.grad_fn, cfg, sgd(2e-2))
    shard_fn = make_dynabro_scan_fn(TASK.grad_fn, cfg, sgd(2e-2), mesh=mesh)
    sw = get_switcher("static", M, n_byz=2)
    with pytest.raises(ValueError, match="mesh"):
        run_dynabro_scan(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, sw,
                         TASK.make_sampler(M), 8, scan_fn=plain_fn, mesh=mesh)
    with pytest.raises(ValueError, match="mesh"):
        run_dynabro_scan(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, sw,
                         TASK.make_sampler(M), 8, scan_fn=shard_fn)
    with pytest.raises(ValueError, match="unsharded"):
        run_dynabro_scan_sweep(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
                               [sw], TASK.make_sampler(M), 8,
                               scan_fn=shard_fn)


def test_format_table_warns_on_residual_collision():
    """Rows the labels cannot split (a varying axis pivoted away) warn
    instead of silently showing one of several differing values."""
    rows = [{"aggregator": "cwmed", "attack": "ipm", "final": 1.0},
            {"aggregator": "cwmed", "attack": "ipm", "final": 2.0}]
    with pytest.warns(RuntimeWarning, match="collide"):
        format_table(rows)


def test_sweep_driver_T0_and_empty():
    from repro.core.mlmc import MLMCConfig
    from repro.core.robust_train import DynaBROConfig
    from repro.optim.optimizers import sgd

    cfg = DynaBROConfig(mlmc=MLMCConfig(T=8, m=M, V=3.0, kappa=1.0),
                        aggregator="cwmed", delta=0.45, attack="sign_flip")
    assert run_dynabro_scan_sweep(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
                                  [], TASK.make_sampler(M), 8) == []
    outs = run_dynabro_scan_sweep(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
                                  [get_switcher("static", M, n_byz=2)],
                                  TASK.make_sampler(M), 0)
    [(p, logs)] = outs
    assert logs == []
    np.testing.assert_array_equal(np.asarray(p["x"]),
                                  np.asarray(TASK.params0["x"]))
