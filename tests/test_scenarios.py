"""``core.scenarios`` edge cases + the vmapped sweep contract (DESIGN.md §7).

The vmapped matrix (``driver="vmap"``) must be a drop-in for the per-cell
compiled loop: same tidy rows, in input order, equal numerics lane for lane.
Both paths run the identical scan body — vmap only adds a lane axis to the
masks and the model state — but batching may reorder float ops at ULP level
(XLA fuses the batched body differently), so float fields are locked to the
parity suite's 1e-6 tolerance while the integer round logs (levels,
fail-safe trips, costs) must match exactly.
"""
import numpy as np
import pytest

from repro.core.robust_train import run_dynabro_scan_sweep
from repro.core.scenarios import (
    Scenario, format_table, make_quadratic_task, run_matrix,
    run_matrix_vmapped, scenario_grid,
)
from repro.core.switching import get_switcher

TASK = make_quadratic_task()
M = 9


def test_empty_grid():
    assert scenario_grid([], [], []) == []
    assert run_matrix(TASK, [], m=M, T=10, V=3.0) == []
    assert run_matrix(TASK, [], m=M, T=10, V=3.0, driver="vmap") == []


def test_single_cell_grid():
    grid = scenario_grid(["sign_flip"], [("static", {"n_byz": 3})], ["cwmed"])
    assert len(grid) == 1 and grid[0].name == "sign_flip|static|cwmed"
    [row_v] = run_matrix(TASK, grid, m=M, T=24, V=3.0, driver="vmap")
    [row_s] = run_matrix(TASK, grid, m=M, T=24, V=3.0, driver="scan")
    assert row_v["driver"] == "vmap" and row_s["driver"] == "scan"
    np.testing.assert_allclose(row_v["final"], row_s["final"], rtol=1e-6,
                               atol=1e-7)
    assert row_v["cost"] == row_s["cost"]
    assert row_v["failsafe_trips"] == row_s["failsafe_trips"]


def test_duplicate_scenario_names():
    """Duplicate cells are legal: they become duplicate lanes/rows with equal
    results, and format_table keeps one column/line per distinct key."""
    sc = Scenario("sign_flip", "static", "cwmed",
                  switcher_kwargs=(("n_byz", 3),))
    rows = run_matrix(TASK, [sc, sc], m=M, T=24, V=3.0, driver="vmap")
    assert len(rows) == 2
    assert rows[0]["final"] == rows[1]["final"]
    assert rows[0]["cost"] == rows[1]["cost"]
    table = format_table(rows)
    assert table.count("cwmed") == 1


@pytest.mark.parametrize("use_mlmc", [True, False])
def test_vmapped_matrix_equals_looped_matrix(use_mlmc):
    """Row-for-row equality of the vmapped sweep against the per-cell loop
    across a grid mixing attacks, switchers (the vmapped lane axis) and
    aggregators (incl. MFM's option-2 config)."""
    grid = scenario_grid(
        ["sign_flip", ("ipm", {"eps": 0.3})],
        [("periodic", {"n_byz": 3, "K": 5}), ("static", {"n_byz": 3}),
         ("bernoulli", {"p": 0.1, "D": 5, "delta_max": 0.5})],
        ["cwmed", "mfm"])
    assert len(grid) == 12
    kw = dict(m=M, T=32, V=3.0, delta=3 / M + 0.01, j_cap=3,
              use_mlmc=use_mlmc, seed=2)
    rows_v = run_matrix(TASK, grid, driver="vmap", **kw)
    rows_s = run_matrix(TASK, grid, driver="scan", **kw)
    assert [r["switcher"] for r in rows_v] == [r["switcher"] for r in rows_s]
    for rv, rs in zip(rows_v, rows_s):
        np.testing.assert_allclose(rv["final"], rs["final"], rtol=1e-6,
                                   atol=1e-7, err_msg=str((rv, rs)))
        assert rv["failsafe_trips"] == rs["failsafe_trips"]
        assert rv["mean_level"] == rs["mean_level"]
        assert rv["cost"] == rs["cost"]


def test_vmapped_chunking_is_invisible():
    grid = scenario_grid(["sign_flip"],
                         [("periodic", {"n_byz": 3, "K": 5}),
                          ("static", {"n_byz": 3})], ["cwmed"])
    r0 = run_matrix_vmapped(TASK, grid, m=M, T=32, V=3.0)
    r16 = run_matrix_vmapped(TASK, grid, m=M, T=32, V=3.0, chunk=16)
    for a, b in zip(r0, r16):
        assert a["final"] == b["final"]


def test_sweep_driver_T0_and_empty():
    from repro.core.mlmc import MLMCConfig
    from repro.core.robust_train import DynaBROConfig
    from repro.optim.optimizers import sgd

    cfg = DynaBROConfig(mlmc=MLMCConfig(T=8, m=M, V=3.0, kappa=1.0),
                        aggregator="cwmed", delta=0.45, attack="sign_flip")
    assert run_dynabro_scan_sweep(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
                                  [], TASK.make_sampler(M), 8) == []
    outs = run_dynabro_scan_sweep(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
                                  [get_switcher("static", M, n_byz=2)],
                                  TASK.make_sampler(M), 0)
    [(p, logs)] = outs
    assert logs == []
    np.testing.assert_array_equal(np.asarray(p["x"]),
                                  np.asarray(TASK.params0["x"]))
