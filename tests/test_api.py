"""``repro.api`` — the session facade and the validated spec layer.

The load-bearing contracts: ``Session.step`` replay over the schedule is
*bitwise* ``Session.run`` (chunking invariance on length-1 slices — the
mechanism the serve loop's offline parity rests on); ``Session.sweep`` with
a ``SweepSpec`` matches the legacy kwarg form of ``run_dynabro_scan_sweep``
exactly; specs validate eagerly with errors that name the valid choices; the
deprecated ``{rule: scan_fn}`` mapping kwarg still works but warns.
"""
import dataclasses

import jax
import numpy as np
import pytest

import repro.core.robust_train as rt
from repro.api import (
    AggSpec, AttackSpec, Session, SweepSpec, build_session,
    run_dynabro_scan_sweep,
)
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig, make_dynabro_scan_fn
from repro.core.scenarios import make_quadratic_task
from repro.core.switching import get_switcher
from repro.optim.optimizers import adagrad_norm, sgd

TASK = make_quadratic_task()
M, T, SEED = 6, 8, 5


def _cfg(T_=T, m=M, **kw):
    return DynaBROConfig(
        mlmc=MLMCConfig(T=T_, m=m, V=3.0, kappa=1.0, j_cap=2),
        aggregator=kw.pop("aggregator", "cwmed"),
        delta=kw.pop("delta", 0.4), attack=kw.pop("attack", "sign_flip"), **kw)


def _session(seed=SEED, **kw):
    switcher = kw.pop("switcher",
                      get_switcher("periodic", M, n_byz=2, K=3, seed=seed))
    return build_session(_cfg(), TASK, switcher=switcher,
                         opt=kw.pop("opt", adagrad_norm(2e-2)), seed=seed,
                         **kw)


def _tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------- session


def test_step_replay_is_bitwise_run():
    """Driving the compiled segment round-by-round through ``step`` (as the
    serve loop does) reproduces the whole-T ``run`` bitwise — params, opt
    state, and per-round fail-safe verdicts."""
    params_ref, logs_ref, _ = _session().run(T)

    sess = _session()
    sched = sess.schedule(T)
    carry = sess.init_carry()
    infos = []
    for t in range(T):
        carry, info = sess.step(carry, sess.round_inputs(sched, t))
        infos.append(info)
    _tree_equal(carry[0], params_ref)
    assert [i.failsafe_ok for i in infos] == [lg.failsafe_ok for lg in logs_ref]
    assert [int(sched.levels[t]) for t in range(T)] == \
           [lg.level for lg in logs_ref]


def test_build_session_validation():
    with pytest.raises(ValueError, match="unknown session mode"):
        Session(_cfg(), grad_fn=TASK.grad_fn, params0=TASK.params0,
                mode="nope")
    with pytest.raises(ValueError, match="need opt="):
        Session(_cfg(), grad_fn=TASK.grad_fn, params0=TASK.params0)
    with pytest.raises(ValueError, match="need lr= and beta="):
        Session(_cfg(), grad_fn=TASK.grad_fn, params0=TASK.params0,
                mode="momentum", lr=0.1)
    # a sweep-built (lane-tagged) scan_fn is rejected up front
    lane_fn = make_dynabro_scan_fn(TASK.grad_fn, _cfg(), sgd(1e-2),
                                   lane_aggregators=("cwmed",))
    with pytest.raises(ValueError, match="not run_dynabro_scan"):
        _session(scan_fn=lane_fn)
    # schedules need a worker source
    sess = Session(_cfg(), grad_fn=TASK.grad_fn, params0=TASK.params0,
                   opt=sgd(1e-2))
    with pytest.raises(ValueError, match="switcher"):
        sess.schedule(T)


# --------------------------------------------------------------- specs


def test_attack_spec_validates_and_coerces():
    assert AttackSpec.coerce("sign_flip") == AttackSpec("sign_flip")
    spec = AttackSpec.coerce(("sign_flip", {"scale": 2.0}))
    assert spec.kwargs == {"scale": 2.0}
    assert spec.legacy == ("sign_flip", {"scale": 2.0})
    assert spec.label == "sign_flip(scale=2.0)"
    with pytest.raises(ValueError, match="unknown attack 'bogus'; known:"):
        AttackSpec("bogus")
    with pytest.raises(ValueError, match="cannot interpret"):
        AttackSpec.coerce(42)
    with pytest.raises(TypeError, match="unknown 'sign_flip' attack param"):
        AttackSpec.make("sign_flip", not_a_param=1.0)


def test_agg_spec_validates_and_emits_both_encodings():
    spec = AggSpec.coerce(("cwtm", {"delta": 0.3}))
    assert spec.kwargs == {"delta": 0.3}
    with pytest.raises(ValueError, match="unknown aggregator"):
        AggSpec("not_a_rule")
    with pytest.raises(TypeError, match="unknown 'cwtm' aggregator param"):
        AggSpec.make("cwtm", bogus_knob=1.0)

    # per-cell form: MFM flips to the δ-oblivious Option 2; delta in the
    # rule kwargs overrides the grid default — consistently with the lane
    # thr_coeff encoding
    cfg = _cfg()
    mfm = AggSpec("mfm")
    cell = mfm.apply_to(cfg)
    assert cell.aggregator == "mfm" and cell.mlmc.option == 2
    assert mfm.thr_coeff(cfg.mlmc) == pytest.approx(
        float(dataclasses.replace(cfg.mlmc, option=2).threshold_coeff))
    cell2 = AggSpec.make("krum", delta=0.3).apply_to(cfg)
    assert cell2.delta == pytest.approx(0.3) and cell2.mlmc.option == 1
    assert AggSpec("cwmed").apply_to(cfg).aggregator_kwargs is None


def test_sweep_spec_lane_count_checked_before_entries():
    """A wrong-length axis reports the count mismatch (the legacy drivers'
    message) even when its entries are also malformed."""
    switchers = ("periodic", "periodic")
    with pytest.raises(ValueError, match=r"attacks: expected one per-lane "
                                          r"spec per switcher \(2\), got 1"):
        SweepSpec(switchers, attacks=("bogus",))
    with pytest.raises(ValueError, match=r"aggregators: expected one "
                                          r"per-lane spec per switcher"):
        SweepSpec(switchers, aggregators=("cwmed", "cwtm", "krum"))
    with pytest.raises(ValueError, match="unknown attack"):
        SweepSpec(switchers, attacks=("bogus", "sign_flip"))
    spec = SweepSpec(switchers, aggregators=("cwmed", ("cwtm", {})))
    assert spec.lanes == 2
    assert spec.agg_lanes() == [("cwmed", {}), ("cwtm", {})]
    assert spec.attack_lanes() is None
    sub = spec.lane_subset([1])
    assert sub.switchers == ("periodic",)
    assert sub.aggregators == (AggSpec("cwtm"),)
    with pytest.raises(ValueError, match="needs a worker count"):
        SweepSpec((("periodic", {"n_byz": 2, "K": 3}),)).resolve_switchers(
            None, SEED)
    resolved = SweepSpec((("periodic", {"n_byz": 2, "K": 3}),
                          ("periodic", {"n_byz": 1, "K": 5}),
                          )).resolve_switchers(M, SEED)
    assert [sw.m for sw in resolved] == [M, M]
    assert [sw.K for sw in resolved] == [3, 5]


# --------------------------------------------------------------- sweep


def test_session_sweep_matches_legacy_kwargs():
    """One mixed-rule sweep, spelled three ways — legacy kwargs on the
    ``run_dynabro_scan_sweep`` wrapper, an explicit ``SweepSpec`` through
    ``Session.sweep``, and the deprecated ``{rule: scan_fn}`` mapping kwarg
    (which must warn) — lands on bitwise-identical per-lane results."""
    switchers = tuple(get_switcher("periodic", M, n_byz=1 + c, K=3, seed=SEED)
                      for c in range(2))
    aggs = ["cwmed", ("cwtm", {"delta": 0.3})]
    cfg = _cfg()
    opt = sgd(1e-2)
    sampler = TASK.make_sampler(M)

    legacy = run_dynabro_scan_sweep(
        TASK.grad_fn, TASK.params0, opt, cfg, switchers, sampler, T,
        seed=SEED, aggregators=aggs)

    sess = Session(cfg, grad_fn=TASK.grad_fn, params0=TASK.params0, opt=opt,
                   sample_batches=sampler, seed=SEED, m=M)
    spec = SweepSpec(switchers, aggregators=aggs)
    via_spec = sess.sweep(spec, T)

    assert len(legacy) == len(via_spec) == 2
    for (p_a, logs_a), (p_b, logs_b) in zip(legacy, via_spec):
        _tree_equal(p_a, p_b)
        assert logs_a == logs_b

    mapping = {
        rule: rt.make_dynabro_scan_fn(TASK.grad_fn, cfg, opt,
                                      lane_aggregators=(rule,))
        for rule in ("cwmed", "cwtm")
    }
    with pytest.warns(DeprecationWarning, match="SweepSpec"):
        via_mapping = run_dynabro_scan_sweep(
            TASK.grad_fn, TASK.params0, opt, cfg, switchers, sampler, T,
            seed=SEED, aggregators=aggs, scan_fn=mapping)
    for (p_a, logs_a), (p_b, logs_b) in zip(legacy, via_mapping):
        _tree_equal(p_a, p_b)
        assert logs_a == logs_b
