"""Sharded compiled driver parity (DESIGN.md §7).

Contract: ``run_dynabro_scan(..., mesh=...)`` / ``run_momentum_scan(...,
mesh=...)`` lay the m simulated workers across the devices of a 1-axis
``workers`` mesh and are **bitwise identical** to the unsharded driver — on a
1-device mesh by construction (the acceptance contract, tested in-process),
and across real device counts because only the per-worker gradient vmap is
split; the attack/aggregation/update body runs on the gathered full stack.

Multi-device cases run in subprocesses with forced host devices so the main
pytest process keeps seeing 1 CPU device (same pattern as test_sharded.py).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import (
    DynaBROConfig, run_dynabro_scan, run_momentum_scan,
)
from repro.core.scenarios import make_quadratic_task, run_scenario, scenario_grid
from repro.core.switching import get_switcher
from repro.launch.mesh import make_worker_mesh
from repro.optim.optimizers import sgd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

TASK = make_quadratic_task()
T = 48
M = 8


def _cfg(agg="cwmed", attack="sign_flip", **kw):
    return DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=M, V=3.0, kappa=1.0),
        aggregator=agg, delta=0.45, attack=attack, **kw)


def _sw():
    return get_switcher("periodic", M, n_byz=3, K=10)


def _assert_logs_equal(l1, l2):
    assert [l.level for l in l1] == [l.level for l in l2]
    assert [l.failsafe_ok for l in l1] == [l.failsafe_ok for l in l2]
    assert [l.n_byz for l in l1] == [l.n_byz for l in l2]
    assert [l.cost for l in l1] == [l.cost for l in l2]


@pytest.mark.parametrize("agg,attack", [
    ("cwmed", "sign_flip"),
    ("cwtm", "ipm"),
    ("mfm", "alie"),
])
def test_sharded_one_device_mesh_is_bitwise(agg, attack):
    """The acceptance contract: a 1-device worker mesh is bitwise-identical
    to the unsharded compiled driver — same ops, shard_map is a no-op wrap."""
    cfg = _cfg(agg, attack)
    sampler = TASK.make_sampler(M)
    p0, l0, _ = run_dynabro_scan(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
                                 _sw(), sampler, T, seed=3)
    p1, l1, _ = run_dynabro_scan(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
                                 _sw(), sampler, T, seed=3,
                                 mesh=make_worker_mesh(1))
    np.testing.assert_array_equal(np.asarray(p0["x"]), np.asarray(p1["x"]))
    _assert_logs_equal(l0, l1)


def test_sharded_momentum_one_device_mesh_is_bitwise():
    cfg = _cfg("cwmed", "shift", attack_kwargs={"v": 3.0})
    sampler = TASK.make_sampler(M)
    p0, _ = run_momentum_scan(TASK.grad_fn, TASK.params0, cfg, _sw(), sampler,
                              T, lr=2e-2, beta=0.9, seed=1)
    p1, _ = run_momentum_scan(TASK.grad_fn, TASK.params0, cfg, _sw(), sampler,
                              T, lr=2e-2, beta=0.9, seed=1,
                              mesh=make_worker_mesh(1))
    np.testing.assert_array_equal(np.asarray(p0["x"]), np.asarray(p1["x"]))


def test_sharded_scenario_cell_matches_unsharded():
    """run_scenario(mesh=...) drives the sharded path end to end."""
    grid = scenario_grid(["sign_flip"], [("static", {"n_byz": 3})], ["cwmed"])
    row0 = run_scenario(TASK, grid[0], m=M, T=40, V=3.0)
    row1 = run_scenario(TASK, grid[0], m=M, T=40, V=3.0,
                        mesh=make_worker_mesh(1))
    assert row0["final"] == row1["final"]
    assert row0["cost"] == row1["cost"]
    assert row0["failsafe_trips"] == row1["failsafe_trips"]


def test_sharded_rejects_bad_meshes():
    import jax

    cfg = _cfg()
    with pytest.raises(ValueError, match="1-axis"):
        run_dynabro_scan(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, _sw(),
                         TASK.make_sampler(M), 8,
                         mesh=jax.make_mesh((1, 1), ("data", "model")))
    # m=9 on a 2-device axis cannot split evenly -> build-time error; needs
    # >=2 devices, so exercise it in a subprocess
    _run("""
        cfg = DynaBROConfig(mlmc=MLMCConfig(T=8, m=9, V=3.0, kappa=1.0),
                            aggregator="cwmed", delta=0.3, attack="sign_flip")
        try:
            run_dynabro_scan(task.grad_fn, task.params0, sgd(2e-2), cfg,
                             get_switcher("static", 9, n_byz=2),
                             task.make_sampler(9), 8, mesh=make_worker_mesh(2))
        except ValueError as e:
            assert "not divisible" in str(e), e
            print("OK")
        else:
            raise SystemExit("expected ValueError")
    """)


# ------------------------------------------------------- multi-device cases


def _run(body: str):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import jax, numpy as np
        from repro.core.mlmc import MLMCConfig
        from repro.core.robust_train import (DynaBROConfig, run_dynabro_scan,
                                             run_momentum_scan)
        from repro.core.scenarios import make_quadratic_task
        from repro.core.switching import get_switcher
        from repro.launch.mesh import make_worker_mesh
        from repro.optim.optimizers import sgd
        T, m = 40, 8
        task = make_quadratic_task()
        sampler = task.make_sampler(m)
    """ % SRC) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-4000:] + "\n" + r.stderr[-4000:]
    return r.stdout


@pytest.mark.slow
def test_sharded_multi_device_parity():
    """m=8 workers across 2/4/8 devices: bitwise parity with the unsharded
    driver, including the omniscient attacks whose statistics span the whole
    (post-gather) worker stack, and identical fail-safe traces."""
    _run("""
        for attack in ("sign_flip", "ipm", "alie"):
            cfg = DynaBROConfig(mlmc=MLMCConfig(T=T, m=m, V=3.0, kappa=1.0),
                                aggregator="cwtm", delta=0.3, attack=attack)
            sw = lambda: get_switcher("periodic", m, n_byz=2, K=7)
            p0, l0, _ = run_dynabro_scan(task.grad_fn, task.params0, sgd(2e-2),
                                         cfg, sw(), sampler, T, seed=4)
            for nd in (2, 4, 8):
                p, l, _ = run_dynabro_scan(task.grad_fn, task.params0,
                                           sgd(2e-2), cfg, sw(), sampler, T,
                                           seed=4, mesh=make_worker_mesh(nd))
                np.testing.assert_array_equal(np.asarray(p0["x"]),
                                              np.asarray(p["x"]))
                assert [x.failsafe_ok for x in l0] == [x.failsafe_ok for x in l]
        print("OK")
    """)


@pytest.mark.slow
def test_sharded_multi_device_momentum_and_chunking():
    _run("""
        cfg = DynaBROConfig(mlmc=MLMCConfig(T=T, m=m, V=3.0, kappa=1.0),
                            aggregator="cwmed", delta=0.3, attack="alie")
        sw = lambda: get_switcher("momentum_tailored", m, alpha=0.1)
        p0, _ = run_momentum_scan(task.grad_fn, task.params0, cfg, sw(),
                                  sampler, T, lr=2e-2, beta=0.9)
        p1, _ = run_momentum_scan(task.grad_fn, task.params0, cfg, sw(),
                                  sampler, T, lr=2e-2, beta=0.9,
                                  mesh=make_worker_mesh(4))
        np.testing.assert_array_equal(np.asarray(p0["x"]), np.asarray(p1["x"]))
        # chunking stays invisible under sharding
        cfg2 = DynaBROConfig(mlmc=MLMCConfig(T=T, m=m, V=3.0, kappa=1.0),
                             aggregator="cwmed", delta=0.3, attack="sign_flip")
        sw2 = lambda: get_switcher("periodic", m, n_byz=2, K=7)
        a, _, _ = run_dynabro_scan(task.grad_fn, task.params0, sgd(2e-2), cfg2,
                                   sw2(), sampler, T, seed=4,
                                   mesh=make_worker_mesh(4))
        b, _, _ = run_dynabro_scan(task.grad_fn, task.params0, sgd(2e-2), cfg2,
                                   sw2(), sampler, T, seed=4, chunk=16,
                                   mesh=make_worker_mesh(4))
        np.testing.assert_array_equal(np.asarray(a["x"]), np.asarray(b["x"]))
        print("OK")
    """)
