"""The replicate statistics axis and its scaling knobs (DESIGN.md §12).

Contracts locked here:

- **Non-degeneracy**: replicate lanes fold genuinely distinct mask / attack
  key / data streams, so per-cell trajectories differ across seeds and the
  reported std is positive.
- **Replicate parity**: replicate lane r of a replicated sweep is bitwise
  the single-lane sweep run with ``seeds=(s_r,)`` alone — and with the
  session's own seed, bitwise the un-replicated sweep (the R==1 fast path
  preserves the pre-replicate schedule stream exactly).
- **Chunk invariance**: ``lane_chunk=`` streams a grid through fixed-size
  dispatches with host-side accumulation and is bitwise-invisible.
- **Mesh contract**: a 1-device ``make_lane_mesh`` is bitwise the unsharded
  sweep (in-process); multi-device lane sharding is bitwise too (subprocess
  with forced host devices, same pattern as test_scan_driver_sharded.py).
- **Halving**: successive-halving survivors are bitwise a plain sweep of
  the surviving subset; pruned cells report their state at the pruning rung.
- **Reporting**: run_matrix(driver="vmap") rows carry mean/std/stderr and
  n_seeds; format_table renders the error bar only for n_seeds >= 2; the
  per-cell drivers reject the replicate kwargs.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api.session import Session, _task_sampler_factory
from repro.api.specs import SweepSpec
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig, make_dynabro_scan_fn
from repro.core.scenarios import (
    format_table, make_quadratic_task, run_matrix, scenario_grid,
)
from repro.core.switching import get_switcher
from repro.launch.mesh import make_lane_mesh
from repro.optim.optimizers import sgd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

TASK = make_quadratic_task()
M = 8
T = 32

SWS = tuple(("periodic", dict(n_byz=3, K=k)) for k in (4, 8, 16))


def _cfg():
    return DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=M, V=3.0, kappa=1.0),
        aggregator="cwmed", delta=0.45, attack="sign_flip")


def _sess(**kw):
    kw.setdefault("sampler_factory", _task_sampler_factory(TASK, M))
    return Session(_cfg(), grad_fn=TASK.grad_fn, params0=TASK.params0,
                   opt=sgd(2e-2), m=M,
                   sample_batches=TASK.make_sampler(M), seed=0, **kw)


def _x(p):
    return np.asarray(p["x"])


def _assert_logs_equal(l1, l2):
    assert [l.level for l in l1] == [l.level for l in l2]
    assert [l.failsafe_ok for l in l1] == [l.failsafe_ok for l in l2]
    assert [l.n_byz for l in l1] == [l.n_byz for l in l2]
    assert [l.cost for l in l1] == [l.cost for l in l2]


def _assert_cells_equal(a, b):
    """a, b: [[(params, logs), ...] per cell] in matching order."""
    assert len(a) == len(b)
    for ca, cb in zip(a, b):
        assert len(ca) == len(cb)
        for (pa, la), (pb, lb) in zip(ca, cb):
            np.testing.assert_array_equal(_x(pa), _x(pb))
            _assert_logs_equal(la, lb)


# ------------------------------------------------------------ non-degeneracy


def test_replicate_lanes_differ_and_std_positive():
    outs = _sess().sweep(SweepSpec(switchers=SWS, seeds=(0, 1, 2)), T)
    assert len(outs) == len(SWS)
    for cell in outs:
        assert len(cell) == 3
        finals = [TASK.objective(p) for p, _ in cell]
        # distinct seeds -> distinct mask/key/batch streams -> distinct lanes
        for i in range(3):
            for j in range(i + 1, 3):
                assert not np.array_equal(_x(cell[i][0]), _x(cell[j][0]))
        assert np.std(finals, ddof=1) > 0.0


def test_replicates_count_derives_seeds():
    sess = _sess()
    by_count = sess.sweep(SweepSpec(switchers=SWS[:1], replicates=2), T)
    by_seeds = sess.sweep(
        SweepSpec(switchers=SWS[:1], seeds=(sess.seed, sess.seed + 1)), T)
    _assert_cells_equal(by_count, by_seeds)


# ----------------------------------------------------------- replicate parity


def test_replicate_lane_matches_single_seed_sweep():
    """Lane r of the replicated sweep == the whole sweep re-run with only
    seed s_r — replicates are independent, just batched into one dispatch."""
    sess = _sess()
    seeds = (0, 3, 11)
    rep = sess.sweep(SweepSpec(switchers=SWS, seeds=seeds), T)
    for r, s in enumerate(seeds):
        solo = sess.sweep(SweepSpec(switchers=SWS, seeds=(s,)), T)
        _assert_cells_equal([[cell[r]] for cell in rep],
                            [[c] for c in solo])


def test_session_seed_replicate_is_bitwise_the_plain_sweep():
    """seeds=(session.seed,) must reproduce the un-replicated sweep exactly:
    the R==1 path folds the same streams the plain path draws."""
    sess = _sess()
    plain = sess.sweep(SweepSpec(switchers=SWS), T)
    rep = sess.sweep(SweepSpec(switchers=SWS, seeds=(sess.seed,)), T)
    _assert_cells_equal([[c] for c in plain], [[c] for c in rep])


def test_replicates_need_per_replicate_samplers():
    sess = _sess(sampler_factory=None)
    with pytest.raises(ValueError, match="sampler"):
        sess.sweep(SweepSpec(switchers=SWS, seeds=(1, 2)), T)


def test_switcher_instances_reject_replication():
    sw = get_switcher("periodic", M, n_byz=3, K=8)
    spec = SweepSpec(switchers=(sw,), seeds=(0, 1))
    with pytest.raises(ValueError, match="(name, kwargs)"):
        _sess().sweep(spec, T)


def test_seed_validation():
    with pytest.raises(ValueError, match="duplicates"):
        SweepSpec(switchers=SWS, seeds=(0, 0, 1))
    with pytest.raises(ValueError, match="disagrees"):
        SweepSpec(switchers=SWS, seeds=(0, 1), replicates=3)
    with pytest.raises(ValueError, match=">= 1"):
        SweepSpec(switchers=SWS, replicates=0)


# ----------------------------------------------------------- chunk invariance


def test_lane_chunk_is_bitwise_invisible():
    sess = _sess()
    sws = tuple(("periodic", dict(n_byz=3, K=k)) for k in (4, 6, 8, 12, 16, 24))
    spec = SweepSpec(switchers=sws, seeds=(0, 1))
    oneshot = sess.sweep(spec, T)
    for lane_chunk in (1, 2, 4, 5):
        chunked = sess.sweep(spec, T, lane_chunk=lane_chunk)
        _assert_cells_equal(oneshot, chunked)


def test_lane_chunk_composes_with_segment_chunk():
    sess = _sess()
    spec = SweepSpec(switchers=SWS, seeds=(0, 1))
    _assert_cells_equal(sess.sweep(spec, T),
                        sess.sweep(spec, T, chunk=8, lane_chunk=2))


def test_lane_chunk_mixed_rule_grouping():
    """Chunk boundaries cut across aggregator groups: each sub-sweep sees a
    subset of the rules and must still group branch-homogeneously."""
    sess = _sess()
    spec = SweepSpec(
        switchers=tuple(("periodic", dict(n_byz=3, K=k))
                        for k in (4, 8, 16, 24)),
        aggregators=("cwmed", "cwtm", "cwmed", "cwtm"),
        seeds=(0, 1))
    _assert_cells_equal(sess.sweep(spec, T),
                        sess.sweep(spec, T, lane_chunk=3))


def test_mapping_scan_fn_may_be_a_superset():
    """A {rule: scan_fn} mapping may carry more rules than a (chunked)
    sub-grid uses — required for lane_chunk to compose with grouping."""
    sess = _sess()
    fns = {rule: make_dynabro_scan_fn(TASK.grad_fn, _cfg(), sgd(2e-2),
                                      lane_aggregators=(rule,))
           for rule in ("cwmed", "cwtm")}
    spec = SweepSpec(switchers=SWS, aggregators=("cwmed",) * len(SWS),
                     scan_fn=fns)
    plain = sess.sweep(SweepSpec(switchers=SWS,
                                 aggregators=("cwmed",) * len(SWS)), T)
    _assert_cells_equal([[c] for c in plain],
                        [[c] for c in sess.sweep(spec, T)])
    with pytest.raises(ValueError, match="cover"):
        sess.sweep(SweepSpec(switchers=SWS, aggregators=("krum",) * len(SWS),
                             scan_fn=fns), T)


# --------------------------------------------------------------- lane meshes


def test_one_device_lane_mesh_is_bitwise():
    """The acceptance contract: a 1-device lane mesh normalizes away and is
    bitwise the unsharded sweep."""
    sess = _sess()
    spec = SweepSpec(switchers=SWS, seeds=(0, 1))
    _assert_cells_equal(sess.sweep(spec, T),
                        sess.sweep(spec, T, lane_mesh=make_lane_mesh(1, 1)))


def test_lane_mesh_validation():
    sess = _sess()
    with pytest.raises(ValueError, match="lanes"):
        import jax
        sess.sweep(SweepSpec(switchers=SWS, seeds=(0, 1)), T,
                   lane_mesh=jax.make_mesh((1,), ("data",)))


def _run(body: str):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import jax, numpy as np
        from repro.api.session import Session, _task_sampler_factory
        from repro.api.specs import SweepSpec
        from repro.core.mlmc import MLMCConfig
        from repro.core.robust_train import DynaBROConfig
        from repro.core.scenarios import make_quadratic_task
        from repro.launch.mesh import make_lane_mesh
        from repro.optim.optimizers import sgd
        T, m = 32, 8
        task = make_quadratic_task()
        cfg = DynaBROConfig(mlmc=MLMCConfig(T=T, m=m, V=3.0, kappa=1.0),
                            aggregator="cwmed", delta=0.45, attack="sign_flip")
        sess = Session(cfg, grad_fn=task.grad_fn, params0=task.params0,
                       opt=sgd(2e-2), m=m, sample_batches=task.make_sampler(m),
                       seed=0, sampler_factory=_task_sampler_factory(task, m))
        sws = tuple(("periodic", dict(n_byz=3, K=k)) for k in (4, 8, 16, 24))
        spec = SweepSpec(switchers=sws, seeds=(0, 1))
        def cells_equal(a, b, exact=True):
            assert len(a) == len(b)
            for ca, cb in zip(a, b):
                for (pa, la), (pb, lb) in zip(ca, cb):
                    xa, xb = np.asarray(pa["x"]), np.asarray(pb["x"])
                    if exact:
                        np.testing.assert_array_equal(xa, xb)
                    else:
                        np.testing.assert_allclose(xa, xb, rtol=1e-6)
                    assert [l.level for l in la] == [l.level for l in lb]
                    assert [l.n_byz for l in la] == [l.n_byz for l in lb]
    """ % SRC) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-4000:] + "\n" + r.stderr[-4000:]
    return r.stdout


@pytest.mark.slow
def test_lane_mesh_multi_device_parity():
    """4 cells x 2 replicates: sharding the cell axis across real devices is
    bitwise (lanes are independent programs laid side by side); adding a
    sharded worker axis keeps numerics within the parity band."""
    _run("""
        base = sess.sweep(spec, T)
        for n_lanes in (2, 4):
            sharded = sess.sweep(spec, T, lane_mesh=make_lane_mesh(n_lanes, 1))
            cells_equal(base, sharded)
        mixed = sess.sweep(spec, T, lane_mesh=make_lane_mesh(2, 2))
        cells_equal(base, mixed, exact=False)
        print("OK")
    """)


@pytest.mark.slow
def test_lane_mesh_rejects_indivisible_lane_count():
    _run("""
        try:
            sess.sweep(spec, T, lane_mesh=make_lane_mesh(3, 1))
        except ValueError as e:
            assert "divisible" in str(e), e
            print("OK")
        else:
            raise SystemExit("expected ValueError: 4 cells on a 3-way axis")
    """)


# ------------------------------------------------------- successive halving


def test_halving_prunes_and_survivors_are_bitwise():
    sess = _sess()
    sws = tuple(("periodic", dict(n_byz=b, K=k))
                for b, k in ((3, 4), (3, 8), (3, 16), (5, 4), (5, 8), (5, 16)))
    spec = SweepSpec(switchers=sws, seeds=(0, 1))
    out = sess.sweep_halving(spec, T, objective=TASK.objective, keep=0.5)
    assert len(out) == 6
    pruned = [o for o in out if o["pruned"]]
    alive = [o for o in out if not o["pruned"]]
    assert len(pruned) == 3 and len(alive) == 3
    assert all(o["rounds_run"] == T // 2 for o in pruned)
    assert all(o["rounds_run"] == T for o in alive)
    # survivors are bitwise a plain sweep of the full grid (lane-subset
    # invariance: pruning other lanes cannot perturb a survivor)
    full = sess.sweep(spec, T)
    for i, o in enumerate(out):
        if not o["pruned"]:
            _assert_cells_equal([o["results"]], [full[i]])


def test_halving_scores_on_replicate_mean():
    """keep=1.0 prunes nothing and reproduces the plain sweep end-state."""
    sess = _sess()
    spec = SweepSpec(switchers=SWS, seeds=(0, 1))
    out = sess.sweep_halving(spec, T, objective=TASK.objective, keep=1.0)
    assert all(not o["pruned"] and o["rounds_run"] == T for o in out)
    _assert_cells_equal([o["results"] for o in out], sess.sweep(spec, T))


def test_halving_validation():
    sess = _sess()
    spec = SweepSpec(switchers=SWS)
    with pytest.raises(ValueError, match="keep"):
        sess.sweep_halving(spec, T, objective=TASK.objective, keep=0.0)
    with pytest.raises(ValueError, match="rungs"):
        sess.sweep_halving(spec, T, objective=TASK.objective, rungs=[T])
    with pytest.raises(ValueError, match="rungs"):
        sess.sweep_halving(spec, T, objective=TASK.objective, rungs=[8, 8])
    fns = {"cwmed": None}
    with pytest.raises(ValueError, match="mapping"):
        sess.sweep_halving(SweepSpec(switchers=SWS, scan_fn=fns), T,
                           objective=TASK.objective)


# ----------------------------------------------------- reporting / run_matrix


def _grid():
    return scenario_grid(["sign_flip"], [("periodic", {"n_byz": 3, "K": 8}),
                                         ("static", {"n_byz": 3})], ["cwmed"])


def test_run_matrix_vmapped_stats_columns():
    rows = run_matrix(TASK, _grid(), m=M, T=T, V=3.0, driver="vmap",
                      seeds=(0, 1, 2))
    for r in rows:
        assert r["n_seeds"] == 3
        assert r["final"] == r["final_mean"]
        assert r["final_std"] > 0.0
        np.testing.assert_allclose(r["final_stderr"],
                                   r["final_std"] / np.sqrt(3.0))


def test_run_matrix_vmapped_single_seed_row_is_bitwise():
    plain = run_matrix(TASK, _grid(), m=M, T=T, V=3.0, driver="vmap")
    for r in plain:
        assert r["n_seeds"] == 1
        assert r["final_std"] == 0.0 and r["final_stderr"] == 0.0
        assert r["final"] == r["final_mean"]
    # the replicate axis left un-used must not perturb the row values
    again = run_matrix(TASK, _grid(), m=M, T=T, V=3.0, driver="vmap")
    assert [r["final"] for r in plain] == [r["final"] for r in again]


def test_per_cell_drivers_reject_replicate_kwargs():
    with pytest.raises(ValueError, match="vmap"):
        run_matrix(TASK, _grid(), m=M, T=T, V=3.0, driver="scan",
                   seeds=(0, 1))
    with pytest.raises(ValueError, match="vmap"):
        run_matrix(TASK, _grid(), m=M, T=T, V=3.0, driver="scan",
                   lane_chunk=4)


def test_format_table_error_bars():
    grid = scenario_grid(["sign_flip", "ipm"],
                         [("periodic", {"n_byz": 3, "K": 8})], ["cwmed"])
    rows = run_matrix(TASK, grid, m=M, T=T, V=3.0, driver="vmap",
                      seeds=(0, 1, 2))
    table = format_table(rows)
    assert "±" in table
    single = format_table(run_matrix(TASK, grid, m=M, T=T, V=3.0,
                                     driver="vmap"))
    assert "±" not in single
