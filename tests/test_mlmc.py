"""MLMC estimator properties (Lemma 3.1) and the fail-safe filter (Eq. 6)."""
import math

import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # hypothesis, or offline fallback

from repro.core.mlmc import (
    MLMCConfig, expected_cost, level_prefix, level_schedule, mlmc_combine,
    round_cost, sample_level, tree_norm, universal_C,
)


def test_sample_level_geometric():
    rng = np.random.default_rng(0)
    js = [sample_level(rng, j_max=20) for _ in range(20000)]
    # P(J=j) = 2^-j
    for j in (1, 2, 3):
        frac = np.mean([x == j for x in js])
        assert abs(frac - 2.0 ** -j) < 0.02


def test_expected_cost_logarithmic():
    """Lemma 3.1(3): E[cost] = 1 + 1.5*J_max <= O(log T)."""
    rng = np.random.default_rng(1)
    T = 1024
    jmax = int(math.log2(T))
    costs = [round_cost(sample_level(rng, jmax), jmax) for _ in range(20000)]
    assert np.mean(costs) < 3.5 * math.log2(T)


def test_round_cost_contract():
    """One cost accounting for every consumer (DESIGN.md §7): plain-SGD and
    beyond-cap rounds cost 1 (one unit batch per worker — the correction is
    dropped past the cap), in-cap MLMC rounds cost 1 + 2^{j-1} + 2^j."""
    assert round_cost(0, 5) == 1
    assert round_cost(1, 5) == 1 + 1 + 2
    assert round_cost(3, 5) == 1 + 4 + 8
    assert round_cost(5, 5) == 1 + 16 + 32
    assert round_cost(6, 5) == 1  # beyond cap: NOT 1 + 32 + 64, and not 2
    assert expected_cost(3) == round_cost(3, 3)  # uncapped back-compat form
    assert expected_cost(6, 5) == 1


def _estimate(option, use_failsafe=True, corrupt_level=None, n_trials=4000, seed=0):
    """Simulate the MLMC combine over a scalar-mean estimation problem where
    M(x, N) = mean of N noisy samples + bias/sqrt(N) (matching Eq. (2))."""
    rng = np.random.default_rng(seed)
    T, m = 256, 8
    cfg = MLMCConfig(T=T, m=m, V=1.0, option=option, kappa=0.5)
    true = np.array([1.0, -2.0])
    outs = []
    costs = []
    for _ in range(n_trials):
        j = min(sample_level(rng, cfg.j_max), cfg.j_max + 1)

        def level(n):
            # biased mini-batch estimator: MSE ~ c^2/n
            noise = rng.normal(size=2) / math.sqrt(n)
            bias = 0.3 / math.sqrt(n)
            return {"g": jnp.asarray(true + bias + noise, jnp.float32)}

        g0 = level(1)
        if j <= cfg.j_max:
            gjm1, gj = level(2 ** (j - 1)), level(2 ** j)
            if corrupt_level == j:
                gj = {"g": gj["g"] + 100.0}
            g, info = mlmc_combine(g0, gjm1, gj, j, cfg)
        else:
            g, info = mlmc_combine(g0, None, None, j, cfg)
        outs.append(np.asarray(g["g"]))
        costs.append(round_cost(j, cfg.j_max))
    outs = np.stack(outs)
    return outs, true, np.mean(costs), cfg


def test_mlmc_reduces_bias():
    """Lemma 3.1(1): MLMC bias ~ c/sqrt(T) << single-level bias c."""
    outs, true, _, cfg = _estimate(option=1)
    mlmc_bias = np.linalg.norm(outs.mean(0) - true)
    single_bias = 0.3 * math.sqrt(2)  # the N=1 estimator's bias
    assert mlmc_bias < 0.5 * single_bias, (mlmc_bias, single_bias)


def test_mlmc_variance_logarithmic():
    """Lemma 3.1(2): variance stays O(c^2 log T) (not O(2^J))."""
    outs, _, _, cfg = _estimate(option=1)
    var = outs.var(0).sum()
    assert var < 50 * math.log(cfg.T)


def test_mlmc_cost_logarithmic():
    _, _, cost, cfg = _estimate(option=1, n_trials=2000)
    assert cost < 4 * math.log2(cfg.T)


def test_failsafe_blocks_corruption():
    """A corrupted high level trips E_t and falls back to ĝ⁰."""
    outs_fs, true, _, _ = _estimate(option=1, corrupt_level=2, use_failsafe=True,
                                    n_trials=1500, seed=3)
    # with the fail-safe, the 100-sized corruption (scaled by 2^j=4) never leaks
    assert np.abs(outs_fs - true).max() < 50.0
    # and the mean stays near the truth
    assert np.linalg.norm(outs_fs.mean(0) - true) < 1.0


def test_failsafe_threshold_monotone_in_level():
    cfg = MLMCConfig(T=1024, m=16, V=2.0, option=1, kappa=0.3)
    th = [float(cfg.threshold(j)) for j in range(1, 8)]
    assert all(a > b for a, b in zip(th, th[1:]))  # ~ 2^{-j/2}
    np.testing.assert_allclose(th[0] / th[2], 2.0, rtol=1e-5)


def test_option2_threshold_is_delta_oblivious():
    a = MLMCConfig(T=64, m=8, V=1.0, option=2, kappa=0.1)
    b = MLMCConfig(T=64, m=8, V=1.0, option=2, kappa=9.0)
    assert float(a.threshold(3)) == float(b.threshold(3))


def test_universal_constant():
    # C = sqrt(8 log(16 m^2 T))
    assert abs(universal_C(17, 5000) - math.sqrt(8 * math.log(16 * 17 * 17 * 5000))) < 1e-9


def test_tree_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    np.testing.assert_allclose(float(tree_norm(t)), math.sqrt(3 + 16), rtol=1e-6)


# --------------------------------------------- properties (hypothesis)


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 4096), st.integers(2, 64), st.floats(0.1, 10.0),
       st.integers(1, 2))
def test_prop_threshold_strictly_decreasing(T, m, V, option):
    cfg = MLMCConfig(T=T, m=m, V=V, option=option, kappa=0.7)
    th = [float(cfg.threshold(j)) for j in range(1, cfg.j_cap + 2)]
    assert all(a > b for a, b in zip(th, th[1:])), th


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(0, 100))
def test_prop_combine_reduces_to_g0_on_trip_or_overflow(j, seed):
    """mlmc_combine must return ĝ⁰ *exactly* when the fail-safe trips (the
    2^J correction is zeroed, not merely damped) and when J exceeds the cap."""
    rng = np.random.default_rng(seed)
    cfg = MLMCConfig(T=64, m=8, V=1e-6, kappa=1.0)  # V→0: any diff trips E_t
    g0 = {"a": jnp.asarray(rng.normal(size=3).astype(np.float32))}
    gjm1 = {"a": jnp.asarray(rng.normal(size=3).astype(np.float32))}
    gj = {"a": jnp.asarray(rng.normal(size=3).astype(np.float32) + 1.0)}
    j = min(j, cfg.j_max)
    g, info = mlmc_combine(g0, gjm1, gj, j, cfg)
    assert not bool(info["failsafe_ok"])
    np.testing.assert_array_equal(np.asarray(g["a"]), np.asarray(g0["a"]))
    # beyond the cap the correction is dropped regardless of the threshold
    g, info = mlmc_combine(g0, None, None, cfg.j_max + 1, cfg)
    assert bool(info["failsafe_ok"])
    np.testing.assert_array_equal(np.asarray(g["a"]), np.asarray(g0["a"]))


def test_level_schedule_matches_legacy_stream_and_geometric():
    """The precomputed schedule is the exact per-round sample_level stream,
    and its empirical law is Geom(1/2) truncated at j_max+1."""
    T, j_max = 40_000, 9
    sched = level_schedule(np.random.default_rng(0), j_max, T)
    ref_rng = np.random.default_rng(0)
    assert [int(x) for x in sched[:200]] == [
        sample_level(ref_rng, j_max) for _ in range(200)]
    assert sched.min() >= 1 and sched.max() <= j_max + 1
    for j in (1, 2, 3, 4):
        frac = float(np.mean(sched == j))
        assert abs(frac - 2.0 ** -j) < 0.02, (j, frac)
    # truncated tail: P(J > j_max) = 2^-j_max
    tail = float(np.mean(sched == j_max + 1))
    assert abs(tail - 2.0 ** -j_max) < 0.02


def test_level_prefix_nested_slices():
    batch = {"x": jnp.arange(24).reshape(2, 12), "y": jnp.arange(12)}
    half = level_prefix(batch, 2, 4, axis=0)
    np.testing.assert_array_equal(np.asarray(half["y"]), np.arange(6))
    assert half["x"].shape == (1, 12)
    stack = {"x": jnp.arange(24).reshape(2, 12), "z": jnp.ones((2, 12, 3))}
    cols = level_prefix(stack, 1, 4, axis=1)
    assert cols["x"].shape == (2, 3) and cols["z"].shape == (2, 3, 3)
    # nesting: the level-(J-1) prefix is a prefix of the level-J prefix
    lo = level_prefix(stack, 2, 4, axis=1)
    np.testing.assert_array_equal(np.asarray(cols["x"]),
                                  np.asarray(lo["x"][:, :3]))
