"""Backend/convention parity for the aggregation engine.

For every registry rule (plus the nnm+ composites): the matrix and tree
conventions agree, and the ``ref`` (pure jnp) and ``pallas`` (interpret-mode
kernels on CPU) backends agree within 1e-5 — on randomized (m, d) matrices
and on a model-shaped gradient pytree.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agg_engine as E
from repro.core.aggregators import MFM, get_aggregator

RULES = ["mean", "cwmed", "cwtm", "krum", "geomed", "nnm+cwmed", "nnm+krum"]


def _mk(m, d, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=(m, d)).astype(np.float32))


def _model_tree(m, seed=0):
    """Gradient-pytree shapes from a small transformer-ish model."""
    rng = np.random.default_rng(seed)
    def mk(*s):
        return jnp.asarray(rng.normal(size=(m,) + s).astype(np.float32))
    return {
        "embed": mk(32, 16),
        "blocks": {"wq": mk(2, 16, 16), "norm": mk(2, 16), "moe": mk(2, 4, 16, 8)},
        "head": {"w": mk(16, 32), "b": mk(32)},
    }


def test_registry_lists_all_rules():
    assert set(E.registered_rules()) == {"mean", "cwmed", "cwtm", "krum",
                                         "geomed", "mfm"}


def test_unknown_rule_raises():
    with pytest.raises(ValueError, match="unknown aggregator"):
        get_aggregator("does-not-exist")


def test_explicit_bad_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        E.resolve_backend("tpu")


@pytest.mark.parametrize("m,d", [(5, 17), (16, 300)])
@pytest.mark.parametrize("name", RULES)
def test_ref_vs_pallas_matrix(name, m, d):
    x = _mk(m, d, seed=m * d)
    ref = np.asarray(get_aggregator(name, delta=0.25, backend="ref")(x))
    pal = np.asarray(get_aggregator(name, delta=0.25, backend="pallas")(x))
    np.testing.assert_allclose(ref, pal, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", RULES)
def test_ref_vs_pallas_model_tree(name):
    tree = _model_tree(m=6)
    ref = get_aggregator(name, delta=0.25, backend="ref").tree(tree)
    pal = get_aggregator(name, delta=0.25, backend="pallas").tree(tree)
    for r, p in zip(jax.tree.leaves(ref), jax.tree.leaves(pal)):
        np.testing.assert_allclose(np.asarray(r), np.asarray(p),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("name", RULES)
def test_matrix_vs_tree_per_backend(name, backend):
    """The matrix convention is the tree convention on one leaf; a split tree
    must reproduce it (global geometry from summed per-leaf distances)."""
    x = _mk(9, 24, seed=hash(name) % 1000)
    agg = get_aggregator(name, delta=0.25, backend=backend)
    flat = np.asarray(agg(x))
    tree = {"a": x[:, :10].reshape(9, 2, 5), "b": x[:, 10:]}
    out = agg.tree(tree)
    got = np.concatenate([np.asarray(out["a"]).reshape(-1),
                          np.asarray(out["b"]).reshape(-1)])
    np.testing.assert_allclose(flat, got, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_mfm_backend_parity(backend):
    x = _mk(8, 40, seed=4)
    ref = np.asarray(MFM(tau=50.0, backend="ref")(x))
    got = np.asarray(MFM(tau=50.0, backend=backend)(x))
    np.testing.assert_allclose(ref, got, rtol=1e-5, atol=1e-5)
    # tree convention with per-call tau
    tree = {"a": x[:, :15], "b": x[:, 15:]}
    out = MFM(backend=backend).tree(tree, tau=50.0)
    got_t = np.concatenate([np.asarray(out["a"]).reshape(-1),
                            np.asarray(out["b"]).reshape(-1)])
    np.testing.assert_allclose(ref, got_t, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_leaf_entry_point_coordinate_wise(backend):
    """Mode B's per-shard entry: leaf() on an (m, ...) stack equals the rule
    on the flattened matrix, reshaped."""
    stack = _mk(7, 24, seed=9).reshape(7, 2, 3, 4)
    for name in ("mean", "cwmed", "cwtm"):
        agg = get_aggregator(name, delta=0.25, backend=backend)
        got = np.asarray(agg.leaf(stack))
        want = np.asarray(agg(stack.reshape(7, -1))).reshape(2, 3, 4)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_leaf_entry_point_rejects_geometry_rules():
    with pytest.raises(NotImplementedError, match="coordinate-wise"):
        get_aggregator("krum").leaf(_mk(5, 8))


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_primitive_cross_sqdist(backend):
    x, y = _mk(6, 33, seed=1), _mk(3, 33, seed=2)
    got = np.asarray(E.cross_sqdist(x, y, backend=backend))
    xn, yn = np.asarray(x), np.asarray(y)
    want = ((xn[:, None] - yn[None]) ** 2).sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_primitive_weighted_combine_shapes(backend):
    x = _mk(5, 50, seed=3)
    w1 = jnp.asarray(np.random.default_rng(0).random(5).astype(np.float32))
    out1 = E.weighted_combine(x, w1, backend=backend)
    assert out1.shape == (50,)
    np.testing.assert_allclose(np.asarray(out1),
                               np.asarray(w1) @ np.asarray(x), rtol=1e-5, atol=1e-5)
    w2 = jnp.asarray(np.random.default_rng(1).random((4, 5)).astype(np.float32))
    out2 = E.weighted_combine(x, w2, backend=backend)
    assert out2.shape == (4, 50)
    np.testing.assert_allclose(np.asarray(out2),
                               np.asarray(w2) @ np.asarray(x), rtol=1e-4, atol=1e-4)


def test_no_full_matrix_materialization():
    """The streaming refactor's contract: aggregating a tree must not build
    the (m, d_total) concatenation — check no intermediate of that size is
    created by tracing with a spy on concatenate."""
    tree = _model_tree(m=4)
    total = sum(int(np.prod(l.shape[1:])) for l in jax.tree.leaves(tree))
    seen = []
    orig = jnp.concatenate

    def spy(arrs, *a, **kw):
        out = orig(arrs, *a, **kw)
        seen.append(out.shape)
        return out

    jnp.concatenate = spy
    try:
        for name in ("krum", "geomed", "mfm"):
            agg = get_aggregator(name, tau=100.0, backend="ref")
            agg.tree(tree)
    finally:
        jnp.concatenate = orig
    assert not any(s[-1] == total for s in seen if len(s) == 2), seen
