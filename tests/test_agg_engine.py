"""Backend/convention parity for the aggregation engine.

For every registry rule (plus the nnm+ composites): the matrix and tree
conventions agree, and the ``ref`` (pure jnp) and ``pallas`` (interpret-mode
kernels on CPU) backends agree within 1e-5 — on randomized (m, d) matrices
and on a model-shaped gradient pytree.

The uniform-theta layer (DESIGN.md §4) is property-tested at the bottom:
for every rule, random worker stacks and random hyperparameters,
``agg_switch`` under the traced ``(stacked, n, theta)`` signature matches
``get_aggregator(name)(...)`` **bitwise** on the ref backend (the class
rules run the identical masked cores) and within kernel tolerance on
pallas (the traced-trim kernel masks where the static one slices).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or offline fallback

from repro.core import agg_engine as E
from repro.core.aggregators import MFM, get_aggregator

RULES = ["mean", "cwmed", "cwtm", "krum", "geomed", "nnm+cwmed", "nnm+krum"]


def _mk(m, d, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=(m, d)).astype(np.float32))


def _model_tree(m, seed=0):
    """Gradient-pytree shapes from a small transformer-ish model."""
    rng = np.random.default_rng(seed)
    def mk(*s):
        return jnp.asarray(rng.normal(size=(m,) + s).astype(np.float32))
    return {
        "embed": mk(32, 16),
        "blocks": {"wq": mk(2, 16, 16), "norm": mk(2, 16), "moe": mk(2, 4, 16, 8)},
        "head": {"w": mk(16, 32), "b": mk(32)},
    }


def test_registry_lists_all_rules():
    assert set(E.registered_rules()) == {"mean", "cwmed", "cwtm", "krum",
                                         "geomed", "mfm"}


def test_unknown_rule_raises():
    with pytest.raises(ValueError, match="unknown aggregator"):
        get_aggregator("does-not-exist")


def test_explicit_bad_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        E.resolve_backend("tpu")


@pytest.mark.parametrize("m,d", [(5, 17), (16, 300)])
@pytest.mark.parametrize("name", RULES)
def test_ref_vs_pallas_matrix(name, m, d):
    x = _mk(m, d, seed=m * d)
    ref = np.asarray(get_aggregator(name, delta=0.25, backend="ref")(x))
    pal = np.asarray(get_aggregator(name, delta=0.25, backend="pallas")(x))
    np.testing.assert_allclose(ref, pal, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", RULES)
def test_ref_vs_pallas_model_tree(name):
    tree = _model_tree(m=6)
    ref = get_aggregator(name, delta=0.25, backend="ref").tree(tree)
    pal = get_aggregator(name, delta=0.25, backend="pallas").tree(tree)
    for r, p in zip(jax.tree.leaves(ref), jax.tree.leaves(pal)):
        np.testing.assert_allclose(np.asarray(r), np.asarray(p),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("name", RULES)
def test_matrix_vs_tree_per_backend(name, backend):
    """The matrix convention is the tree convention on one leaf; a split tree
    must reproduce it (global geometry from summed per-leaf distances)."""
    # deterministic per-name seed — hash() is salted per interpreter, which
    # made this test flaky across runs (a few seeds flip Krum's discrete
    # selection past the tolerance)
    x = _mk(9, 24, seed=sum(map(ord, name)) % 1000)
    agg = get_aggregator(name, delta=0.25, backend=backend)
    flat = np.asarray(agg(x))
    tree = {"a": x[:, :10].reshape(9, 2, 5), "b": x[:, 10:]}
    out = agg.tree(tree)
    got = np.concatenate([np.asarray(out["a"]).reshape(-1),
                          np.asarray(out["b"]).reshape(-1)])
    np.testing.assert_allclose(flat, got, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_mfm_backend_parity(backend):
    x = _mk(8, 40, seed=4)
    ref = np.asarray(MFM(tau=50.0, backend="ref")(x))
    got = np.asarray(MFM(tau=50.0, backend=backend)(x))
    np.testing.assert_allclose(ref, got, rtol=1e-5, atol=1e-5)
    # tree convention with per-call tau
    tree = {"a": x[:, :15], "b": x[:, 15:]}
    out = MFM(backend=backend).tree(tree, tau=50.0)
    got_t = np.concatenate([np.asarray(out["a"]).reshape(-1),
                            np.asarray(out["b"]).reshape(-1)])
    np.testing.assert_allclose(ref, got_t, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_leaf_entry_point_coordinate_wise(backend):
    """Mode B's per-shard entry: leaf() on an (m, ...) stack equals the rule
    on the flattened matrix, reshaped."""
    stack = _mk(7, 24, seed=9).reshape(7, 2, 3, 4)
    for name in ("mean", "cwmed", "cwtm"):
        agg = get_aggregator(name, delta=0.25, backend=backend)
        got = np.asarray(agg.leaf(stack))
        want = np.asarray(agg(stack.reshape(7, -1))).reshape(2, 3, 4)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_leaf_entry_point_rejects_geometry_rules():
    with pytest.raises(NotImplementedError, match="coordinate-wise"):
        get_aggregator("krum").leaf(_mk(5, 8))


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_primitive_cross_sqdist(backend):
    x, y = _mk(6, 33, seed=1), _mk(3, 33, seed=2)
    got = np.asarray(E.cross_sqdist(x, y, backend=backend))
    xn, yn = np.asarray(x), np.asarray(y)
    want = ((xn[:, None] - yn[None]) ** 2).sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_primitive_weighted_combine_shapes(backend):
    x = _mk(5, 50, seed=3)
    w1 = jnp.asarray(np.random.default_rng(0).random(5).astype(np.float32))
    out1 = E.weighted_combine(x, w1, backend=backend)
    assert out1.shape == (50,)
    np.testing.assert_allclose(np.asarray(out1),
                               np.asarray(w1) @ np.asarray(x), rtol=1e-5, atol=1e-5)
    w2 = jnp.asarray(np.random.default_rng(1).random((4, 5)).astype(np.float32))
    out2 = E.weighted_combine(x, w2, backend=backend)
    assert out2.shape == (4, 50)
    np.testing.assert_allclose(np.asarray(out2),
                               np.asarray(w2) @ np.asarray(x), rtol=1e-4, atol=1e-4)


def test_no_full_matrix_materialization():
    """The streaming refactor's contract: aggregating a tree must not build
    the (m, d_total) concatenation — check no intermediate of that size is
    created by tracing with a spy on concatenate."""
    tree = _model_tree(m=4)
    total = sum(int(np.prod(l.shape[1:])) for l in jax.tree.leaves(tree))
    seen = []
    orig = jnp.concatenate

    def spy(arrs, *a, **kw):
        out = orig(arrs, *a, **kw)
        seen.append(out.shape)
        return out

    jnp.concatenate = spy
    try:
        for name in ("krum", "geomed", "mfm"):
            agg = get_aggregator(name, tau=100.0, backend="ref")
            agg.tree(tree)
    finally:
        jnp.concatenate = orig
    assert not any(s[-1] == total for s in seen if len(s) == 2), seen


# ------------------------------------------------- uniform theta dispatch
#
# DESIGN.md §4: every rule under the traced (stacked, n, theta) signature.

UNIFORM_RULES = ["mean", "cwmed", "cwtm", "krum", "geomed", "mfm",
                 "nnm+cwmed", "nnm+krum", "nnm+geomed"]
# deltas clear of ⌈δm⌉ integer boundaries: the class path ceils in f64, the
# traced path in (nudged) f32 — equal counts, hence bitwise parity, need
# δ·m not within ~1e-5 of an integer, which every realistic δ satisfies
SAFE_DELTAS = [0.1, 0.2, 0.25, 0.3, 0.37, 0.45]


def _rule_kwargs(name, delta, multi, iters, tau, m):
    """Random-hyperparameter kwargs restricted to the slots ``name`` takes."""
    pool = {"delta": delta, "multi": min(multi, max(m - 4, 1)),
            "iters": iters, "tau": tau}
    return {p: pool[p] for p in E.agg_param_names(name) if p in pool}


@settings(max_examples=10, deadline=None)
@given(st.integers(5, 12), st.integers(2, 24),
       st.sampled_from(SAFE_DELTAS), st.integers(1, 4), st.integers(1, 8),
       st.floats(5.0, 80.0), st.integers(0, 10_000))
@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("name", UNIFORM_RULES)
def test_uniform_theta_matches_class_rule(name, backend, m, d, delta, multi,
                                          iters, tau, seed):
    """agg_switch(agg_id, stacked, n, theta) == get_aggregator(name)(...):
    bitwise on ref, within kernel tolerance on pallas — random stacks and
    random hyperparameters, two-leaf trees (global geometry exercised)."""
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.normal(size=(m, d)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(m, 3, 2)).astype(np.float32))}
    kw = _rule_kwargs(name, delta, multi, iters, tau, m)
    theta = jnp.asarray(E.agg_theta(name, kw))
    apply_fn = E.agg_switch((name,), backend=backend)
    got = apply_fn(jnp.asarray(0, jnp.int32), tree, 4, theta)
    want = get_aggregator(name, backend=backend, **kw).tree(tree)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        if backend == "ref":
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                          err_msg=f"{name} {kw}")
        else:
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"{name} {kw}")


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_agg_switch_multi_branch_dispatch(backend):
    """A 4-branch agg_switch routes each lane id to its own rule — every
    branch compared against the direct uniform form."""
    names = ("cwmed", "cwtm", "krum", "mfm")
    apply_fn = E.agg_switch(names, backend=backend)
    rng = np.random.default_rng(7)
    tree = {"w": jnp.asarray(rng.normal(size=(9, 11)).astype(np.float32))}
    for i, nm in enumerate(names):
        kw = {"tau": 40.0} if nm == "mfm" else {}
        theta = jnp.asarray(E.agg_theta(nm, kw))
        got = apply_fn(jnp.asarray(i, jnp.int32), tree, 2, theta)
        want = E.uniform_aggregator(nm, backend=backend)(tree, 2, theta)
        np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want["w"]),
                                   rtol=1e-6, atol=1e-7, err_msg=nm)


def test_uniform_mfm_nan_sentinel_auto_tau():
    """NaN in the tau slot + an MLMCConfig derives the Option-2 threshold
    2CV/√n — equal to the class rule at the explicitly-computed tau."""
    from repro.core.mlmc import MLMCConfig

    mlmc = MLMCConfig(T=64, m=8, V=3.0, option=2)
    rng = np.random.default_rng(3)
    tree = {"w": jnp.asarray(rng.normal(size=(8, 9)).astype(np.float32))}
    fn = E.uniform_aggregator("mfm", backend="ref", mlmc=mlmc)
    for n in (1, 4, 16):
        got = fn(tree, n, jnp.asarray(E.agg_theta("mfm", {})))  # tau=None
        want = MFM(backend="ref").tree(tree, tau=mlmc.mfm_tau(n))
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(want["w"]))


def test_agg_theta_validation():
    th = E.agg_theta("cwtm", {"delta": 0.4})
    assert th.shape == (E.N_AGG_PARAMS,) and th[0] == np.float32(0.4)
    assert np.isnan(E.agg_theta("mfm", {})[0])  # tau=None -> NaN sentinel
    # delta is tolerated (and discarded) for rules without a delta slot —
    # get_aggregator's universal delta parameter ignores it there too, and
    # the lane path must not reject a spec the per-cell path runs
    np.testing.assert_array_equal(E.agg_theta("cwmed", {"delta": 0.3}),
                                  E.agg_theta("cwmed", {}))
    with pytest.raises(TypeError, match="unknown"):
        E.agg_theta("cwmed", {"trim": 2})  # anything else still raises
    with pytest.raises(TypeError, match="does not accept None"):
        E.agg_theta("cwtm", {"delta": None})
    with pytest.raises(ValueError, match="GEOMED_MAX_ITERS"):
        E.agg_theta("geomed", {"iters": E.GEOMED_MAX_ITERS + 1})
    with pytest.raises(ValueError, match="unknown aggregator"):
        E.agg_theta("nope", {})
    # composite slots: nnm's delta is shared with (not duplicated by) the base
    assert E.agg_param_names("nnm+cwtm") == ("delta",)
    assert E.agg_param_names("nnm+geomed") == ("delta", "iters", "eps")
    # the NaN auto-tau sentinel is plain mfm only: the per-cell driver has no
    # auto-tau path for nnm+mfm, so the lane path must reject what the
    # reference driver would crash on (explicit tau works on both)
    with pytest.raises(TypeError, match="does not accept None"):
        E.agg_theta("nnm+mfm", {})
    assert E.agg_theta("nnm+mfm", {"delta": 0.3, "tau": 40.0})[1] == \
        np.float32(40.0)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(SAFE_DELTAS), st.integers(3, 33))
def test_traced_trim_count_matches_host(delta, m):
    assert int(E.traced_trim_count(jnp.float32(delta), m)) == \
        E.trim_count(delta, m)


@pytest.mark.parametrize("trim", [0, 1, 3])
def test_cwtm_masked_kernel_matches_static(trim):
    """The traced-trim pallas kernel agrees with the statically-sliced one
    (masked summation may differ at ULP level, hence allclose)."""
    from repro.kernels.ops import cwtm_masked_op, cwtm_op

    x = _mk(8, 130, seed=trim)
    got = np.asarray(cwtm_masked_op(x, jnp.asarray(trim, jnp.int32)))
    want = np.asarray(cwtm_op(x, trim))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ------------------------------------------------- fused one-pass kernel
#
# One pallas_call streams the (m, d) stack once and emits any subset of the
# reduce / pairwise / combine stages (DESIGN.md §7). Parity vs the ref
# oracles in interpret mode at adversarial tile boundaries: d not a multiple
# of tile_d (zero-padded columns must stay inert for every stage), m odd /
# even / 1, and trim at its clip limit (m-1)//2 — a single surviving row
# for odd m.


def _pw_close(got, want, atol=2e-6):
    scale = np.asarray(want).max() + 1e-9
    np.testing.assert_allclose(np.asarray(got) / scale,
                               np.asarray(want) / scale, atol=atol)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 9), st.integers(1, 333), st.sampled_from([32, 64, 128]),
       st.booleans(), st.integers(0, 10_000))
def test_fused_pass_all_stages_tile_boundaries(m, d, tile_d, traced, seed):
    """All three stages from one dispatch == the three separate refs, with
    trim at the single-survivor limit and a random (usually non-dividing)
    d/tile_d ratio; the trim count rides as data when ``traced``."""
    from repro.kernels import ref as kref
    from repro.kernels.fused import fused_pass

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32) * 3)
    w = jnp.asarray(rng.random((m, m)).astype(np.float32))
    w = w / w.sum(1, keepdims=True)
    trim = (m - 1) // 2  # clip limit: one survivor for odd m, two for even
    out = fused_pass(
        x, w=w, reduce="tm",
        trim=jnp.asarray(trim, jnp.int32) if traced else trim,
        pairwise=True, combine=True, tile_d=tile_d, interpret=True)
    mixed = kref.weighted_combine_ref(x, w)
    np.testing.assert_allclose(np.asarray(out["combine"]), np.asarray(mixed),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out["reduce"]),
                               np.asarray(kref.cwtm_ref(mixed, trim)),
                               rtol=1e-5, atol=1e-5)
    _pw_close(out["pairwise"], kref.pairwise_sqdist_ref(x))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 8), st.integers(1, 300), st.sampled_from([64, 256]),
       st.sampled_from(["med", "tm", "mean"]), st.integers(0, 10_000))
def test_fused_pass_reduce_only_matches_ref(m, d, tile_d, mode, seed):
    """Reduce-of-x (no weights) at odd/even/1 m and non-dividing d."""
    from repro.kernels import ref as kref
    from repro.kernels.fused import fused_pass

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32) * 3)
    trim = (m - 1) // 2 if mode == "tm" else 0
    got = fused_pass(x, reduce=mode, trim=trim, tile_d=tile_d,
                     interpret=True)["reduce"]
    want = {"med": lambda: kref.cwmed_ref(x),
            "tm": lambda: kref.cwtm_ref(x, trim),
            "mean": lambda: jnp.mean(x, axis=0)}[mode]()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_pass_k_lt_m_combine_reduce():
    """A (k, m) weight matrix with k < m: the reduce stage runs over the k
    mixed rows, not the m inputs."""
    from repro.kernels import ref as kref
    from repro.kernels.fused import fused_pass

    x = _mk(7, 123, seed=11)
    w = jnp.asarray(np.random.default_rng(1).random((3, 7)).astype(np.float32))
    out = fused_pass(x, w=w, reduce="med", combine=True, tile_d=64,
                     interpret=True)
    mixed = kref.weighted_combine_ref(x, w)
    assert out["combine"].shape == (3, 123)
    np.testing.assert_allclose(np.asarray(out["reduce"]),
                               np.asarray(kref.cwmed_ref(mixed)),
                               rtol=1e-5, atol=1e-5)


def test_fused_pass_validates_requests():
    from repro.kernels.fused import fused_pass

    x = _mk(4, 16)
    with pytest.raises(ValueError, match="at least one"):
        fused_pass(x, interpret=True)
    with pytest.raises(ValueError, match="unknown reduce mode"):
        fused_pass(x, reduce="max", interpret=True)
    with pytest.raises(ValueError, match="needs weights"):
        fused_pass(x, combine=True, interpret=True)


# ------------------------------------------------- size-aware dispatch


def test_dispatch_backend_heuristic():
    """Explicit backends are honoured; auto goes ref below PALLAS_MIN_BYTES
    and (off-TPU) takes the kernel only for sort-shaped primitives."""
    big = E.PALLAS_MIN_BYTES
    assert E.dispatch_backend("ref", kind="sort", nbytes=big) == "ref"
    assert E.dispatch_backend("pallas", kind="matmul", nbytes=0) == "pallas"
    assert E.dispatch_backend("auto", kind="sort", nbytes=big - 1) == "ref"
    assert E.dispatch_backend("auto", kind="matmul", nbytes=big - 1) == "ref"
    if jax.default_backend() != "tpu":
        assert E.dispatch_backend("auto", kind="sort", nbytes=big) == "pallas"
        assert E.dispatch_backend("auto", kind="matmul", nbytes=big) == "ref"
    with pytest.raises(ValueError, match="unknown dispatch kind"):
        E.dispatch_backend("auto", kind="conv", nbytes=big)
    with pytest.raises(ValueError, match="unknown backend"):
        E.dispatch_backend("tpu", kind="sort", nbytes=big)


@pytest.mark.parametrize("mode,trim", [("med", 0), ("tm", 2), ("mean", 0)])
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_combine_reduce_matches_two_step(backend, mode, trim):
    """The fused mix+reduce primitive == combine followed by the matching
    coordinate-wise reduce, on both backends (NNM's hot step)."""
    x = _mk(7, 61, seed=5)
    w = jnp.asarray(np.random.default_rng(2).random((7, 7)).astype(np.float32))
    w = w / w.sum(1, keepdims=True)
    got = np.asarray(E.combine_reduce(x, w, mode, trim, backend=backend))
    mixed = E.weighted_combine(x, w, backend="ref")
    want = {"med": lambda: E.cw_median(mixed, backend="ref"),
            "tm": lambda: E.cw_trimmed_mean(mixed, trim, backend="ref"),
            "mean": lambda: E.cw_mean(mixed, backend="ref")}[mode]()
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-5, atol=1e-5)
    # traced trim takes the masked kernel path; same tolerance
    if mode == "tm":
        got_t = np.asarray(E.combine_reduce(
            x, w, mode, jnp.asarray(trim, jnp.int32), backend=backend))
        np.testing.assert_allclose(got_t, np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_tree_combine_reduce_matches_leafwise(backend):
    """Tree form: per-leaf mix+reduce, output shaped like one worker entry."""
    tree = _model_tree(m=6, seed=8)
    w = jnp.asarray(np.random.default_rng(3).random((6, 6)).astype(np.float32))
    w = w / w.sum(1, keepdims=True)
    out = E.tree_combine_reduce(tree, w, mode="med", backend=backend)
    mixed = E.tree_weighted_combine(tree, w, backend="ref")
    want = get_aggregator("cwmed", backend="ref").tree(mixed)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for o, l, wv in zip(jax.tree.leaves(out), jax.tree.leaves(tree),
                        jax.tree.leaves(want)):
        assert o.shape == l.shape[1:]
        np.testing.assert_allclose(np.asarray(o), np.asarray(wv),
                                   rtol=1e-5, atol=1e-5)
