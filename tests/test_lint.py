"""repro.lint: rule fixtures (positive / negative / suppressed), engine
suppression semantics, the runtime sanitizers, and the core fixes the pass
motivated (the Bernoulli cap truncation, DESIGN.md §11)."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.lint.engine import lint_paths, lint_source

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def codes(src, path="fixture.py", select=None):
    return [v.rule for v in lint_source(src, path=path, select=select)]


# ------------------------------------------------------------------- JXL001


def test_jxl001_fires_on_key_reuse():
    src = (
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (3,))\n"
        "    b = jax.random.uniform(key, (3,))\n"
        "    return a + b\n"
    )
    assert codes(src) == ["JXL001"]


def test_jxl001_fires_on_loop_reuse():
    src = (
        "import jax\n"
        "def f(key, xs):\n"
        "    out = []\n"
        "    for x in xs:\n"
        "        out.append(jax.random.normal(key, (3,)))\n"
        "    return out\n"
    )
    assert codes(src) == ["JXL001"]


def test_jxl001_clean_on_split_and_fold_in():
    src = (
        "import jax\n"
        "def f(key, xs):\n"
        "    k1, k2 = jax.random.split(key)\n"
        "    a = jax.random.normal(k1, (3,))\n"
        "    b = jax.random.uniform(k2, (3,))\n"
        "    out = []\n"
        "    for i, x in enumerate(xs):\n"
        "        ki = jax.random.fold_in(key, i)\n"
        "        out.append(jax.random.normal(ki, (3,)))\n"
        "    return a, b, out\n"
    )
    assert codes(src) == []


def test_jxl001_clean_on_exclusive_branches():
    src = (
        "import jax\n"
        "def f(key, kind):\n"
        "    if kind == 'a':\n"
        "        x = jax.random.normal(key, (3,))\n"
        "    elif kind == 'b':\n"
        "        x = jax.random.uniform(key, (3,))\n"
        "    return x\n"
    )
    assert codes(src) == []


def test_jxl001_counts_key_kwarg_handoff():
    src = (
        "def f(key, g):\n"
        "    a = attack(g, key=key)\n"
        "    b = attack(g, key=key)\n"
        "    return a, b\n"
    )
    assert codes(src) == ["JXL001"]


def test_jxl001_suppression_honored():
    src = (
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.normal(key, (3,))\n"
        "    # jaxlint: disable=JXL001 -- antithetic pair wants shared draws\n"
        "    b = jax.random.uniform(key, (3,))\n"
        "    return a + b\n"
    )
    assert codes(src) == []


# ------------------------------------------------------------------- JXL002


def test_jxl002_fires_on_traced_branch_in_scan_body():
    src = (
        "import jax\n"
        "def body(carry, x):\n"
        "    if x > 0:\n"
        "        carry = carry + x\n"
        "    return carry, x\n"
        "def run(xs):\n"
        "    return jax.lax.scan(body, 0.0, xs)\n"
    )
    assert codes(src) == ["JXL002"]


def test_jxl002_fires_on_int_cast_in_jitted_fn():
    src = "import jax\n@jax.jit\ndef f(x):\n    return int(x) + 1\n"
    assert codes(src) == ["JXL002"]


def test_jxl002_clean_on_static_escapes():
    src = (
        "import jax\n"
        "def body(carry, x):\n"
        "    if x.shape[0] > 2:\n"
        "        carry = carry * 2\n"
        "    if x is not None:\n"
        "        carry = carry + 1\n"
        "    return carry, x\n"
        "def run(xs):\n"
        "    return jax.lax.scan(body, 0.0, xs)\n"
    )
    assert codes(src) == []


def test_jxl002_clean_on_static_argnames():
    src = (
        "import jax\n"
        "def step(params, j):\n"
        "    if j > 0:\n"
        "        params = params * j\n"
        "    return params\n"
        "step = jax.jit(step, static_argnames=('j',))\n"
    )
    assert codes(src) == []


def test_jxl002_untraced_function_is_ignored():
    src = "def host(x):\n    if x > 0:\n        return x\n    return -x\n"
    assert codes(src) == []


def test_jxl002_suppression_honored():
    src = (
        "import jax\n"
        "def body(carry, x):\n"
        "    # jaxlint: disable=JXL002 -- x is a host dict, truthiness static\n"
        "    if x:\n"
        "        carry = carry + 1\n"
        "    return carry, x\n"
        "def run(xs):\n"
        "    return jax.lax.scan(body, 0.0, xs)\n"
    )
    assert codes(src) == []


# ------------------------------------------------------------------- JXL003


def test_jxl003_fires_on_math_ceil_and_int_product():
    src = (
        "import math\n"
        "def caps(delta, m):\n"
        "    return math.ceil(delta * m), int(delta * m)\n"
    )
    assert codes(src) == ["JXL003", "JXL003"]


def test_jxl003_clean_on_nudged_and_non_product_forms():
    src = "def caps(delta, m):\n    return int(round(delta)), int(m), m // 2\n"
    assert codes(src) == []


def test_jxl003_suppression_honored():
    src = (
        "import math\n"
        "def count_ceil(v):\n"
        "    # jaxlint: disable=JXL003 -- the sanctioned nudged helper\n"
        "    return math.ceil(v - 1e-5)\n"
    )
    assert codes(src) == []


# ------------------------------------------------------------------- JXL004


def test_jxl004_fires_on_hash_seed():
    src = "def seed_for(name):\n    return hash(name) % 2 ** 31\n"
    assert codes(src) == ["JXL004"]


def test_jxl004_fires_on_seedless_np_random():
    src = (
        "import numpy as np\n"
        "def draw(m):\n"
        "    return np.random.rand(m), np.random.default_rng()\n"
    )
    assert codes(src) == ["JXL004", "JXL004"]


def test_jxl004_fires_on_wall_clock_in_deterministic_layer():
    src = "import time\ndef seed():\n    return int(time.time())\n"
    assert codes(src, path="src/repro/core/sched.py") == ["JXL004"]


def test_jxl004_wall_clock_allowed_outside_deterministic_layers():
    src = "import time\ndef bench():\n    return time.time()\n"
    assert codes(src, path="benchmarks/bench_x.py") == []


def test_jxl004_perf_counter_allowed_everywhere():
    src = "import time\ndef wall():\n    return time.perf_counter()\n"
    assert codes(src, path="src/repro/core/scenarios.py") == []


def test_jxl004_fires_on_set_iteration():
    src = (
        "def f(d):\n"
        "    out = []\n"
        "    for k in set(d):\n"
        "        out.append(k)\n"
        "    return out\n"
    )
    assert codes(src) == ["JXL004"]


def test_jxl004_seeded_rng_clean():
    src = (
        "import numpy as np\n"
        "def draw(m, seed):\n"
        "    return np.random.default_rng(seed).random(m)\n"
    )
    assert codes(src) == []


def test_jxl004_suppression_honored():
    src = (
        "def seed_for(name):\n"
        "    # jaxlint: disable=JXL004 -- never replayed, diagnostics only\n"
        "    return hash(name)\n"
    )
    assert codes(src) == []


# ------------------------------------------------------------------- JXL005


def test_jxl005_fires_on_np_call_in_scan_body():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "def body(carry, x):\n"
        "    y = np.asarray(x)\n"
        "    return carry + y.sum(), x\n"
        "def run(xs):\n"
        "    return jax.lax.scan(body, 0.0, xs)\n"
    )
    assert codes(src) == ["JXL005"]


def test_jxl005_fires_on_item_in_shard_map_body():
    src = (
        "from jax.experimental.shard_map import shard_map\n"
        "def body(x):\n"
        "    return x.sum().item()\n"
        "def run(mesh, xs):\n"
        "    return shard_map(body, mesh, in_specs=None, out_specs=None)(xs)\n"
    )
    assert codes(src) == ["JXL005"]


def test_jxl005_np_on_host_constants_clean():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "SCHED = np.arange(8)\n"
        "def body(carry, x):\n"
        "    return carry + x, x\n"
        "def run(xs):\n"
        "    plan = np.asarray(SCHED)\n"
        "    return jax.lax.scan(body, 0.0, xs)\n"
    )
    assert codes(src) == []


def test_jxl005_suppression_honored():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "def body(carry, x):\n"
        "    # jaxlint: disable=JXL005 -- x is a host-side schedule here\n"
        "    y = np.asarray(x)\n"
        "    return carry + y.sum(), x\n"
        "def run(xs):\n"
        "    return jax.lax.scan(body, 0.0, xs)\n"
    )
    assert codes(src) == []


# ------------------------------------------------------------------- JXL006


def test_jxl006_fires_on_unguarded_spread():
    src = (
        "def main(rows):\n"
        '    return [f"x/{n},,gap={m:.3f}+-{s:.3f}" for n, m, s in rows]\n'
    )
    assert codes(src) == ["JXL006"]


def test_jxl006_fires_on_pm_sign():
    src = 'def fmt(m, s):\n    return f"acc {m:.2f}±{s:.2f}"\n'
    assert codes(src) == ["JXL006"]


def test_jxl006_fires_at_module_scope():
    src = 'ROW = f"gap={1.0:.3f}+-{0.0:.3f}"\n'
    assert codes(src) == ["JXL006"]


def test_jxl006_clean_when_scope_handles_n_seeds():
    src = (
        "def fmt(vals):\n"
        "    n = len(vals)\n"
        "    m = sum(vals) / n\n"
        "    if n == 1:\n"
        '        return f"gap={m:.3f};n_seeds=1"\n'
        "    s = 1.0\n"
        '    return f"gap={m:.3f}+-{s:.3f};n_seeds={n}"\n'
    )
    assert codes(src) == []


def test_jxl006_clean_on_literal_pm_without_formatted_value():
    src = (
        'def fmt(r):\n    return f"a +- b literal {r}"\n'
        'def fmt2(m):\n    return f"gap={m}+-const"\n'
    )
    assert codes(src) == []


def test_jxl006_suppression_honored():
    src = (
        "def main(m, s):\n"
        '    return f"gap={m:.3f}+-{s:.3f}"'
        "  # jaxlint: disable=JXL006 -- spread is always multi-sample here\n"
    )
    assert codes(src) == []


# -------------------------------------------------------------- engine/CLI


def test_reasonless_suppression_is_jxl000():
    src = "import math\ndef f(v):\n    return math.ceil(v)  # jaxlint: disable=JXL003\n"
    got = codes(src)
    assert "JXL000" in got and "JXL003" in got


def test_select_filters_rules():
    src = "import math\ndef f(v, name):\n    return math.ceil(v), hash(name)\n"
    assert codes(src, select=["JXL004"]) == ["JXL004"]


def test_syntax_error_reported_not_raised():
    assert codes("def f(:\n") == ["JXL999"]


def test_repo_ships_clean():
    trees = [
        os.path.join(REPO, t)
        for t in ("src", "benchmarks", "examples")
        if os.path.exists(os.path.join(REPO, t))
    ]
    violations = lint_paths(trees)
    assert not violations, "\n".join(v.render() for v in violations)


def test_cli_importable_without_jax():
    code = (
        "import sys; sys.modules['jax'] = None; sys.modules['numpy'] = None\n"
        "from repro.lint.engine import lint_source\n"
        "from repro.lint.rules import RULES\n"
        "import repro.lint\n"
        "assert len(RULES) >= 5\n"
        "assert lint_source('x = 1') == []\n"
        "print('ok')\n"
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True
    )
    assert out.returncode == 0, out.stderr
    assert "ok" in out.stdout


def test_cli_list_rules():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--list-rules"],
        env=env,
        capture_output=True,
        text=True,
    )
    assert out.returncode == 0, out.stderr
    for code in ("JXL001", "JXL002", "JXL003", "JXL004", "JXL005"):
        assert code in out.stdout


def test_no_tracked_bytecode():
    try:
        out = subprocess.run(
            ["git", "ls-files", "*.pyc", "**/__pycache__/**"],
            cwd=REPO,
            capture_output=True,
            text=True,
            timeout=30,
        )
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if out.returncode != 0:
        pytest.skip("not a git checkout")
    assert out.stdout.strip() == "", f"tracked bytecode: {out.stdout}"


# ------------------------------------------------------- runtime sanitizers

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.lint.runtime import (  # noqa: E402
    RecompileError,
    assert_all_finite,
    maybe_assert_finite,
    recompile_guard,
)


def test_recompile_guard_catches_forced_recompile():
    f = jax.jit(lambda x: x * 2.0)
    f(jnp.ones(3))  # warm one shape
    with pytest.raises(RecompileError, match="recompile"):
        with recompile_guard("forced"):
            f(jnp.ones(4))  # new shape inside the guarded window


def test_recompile_guard_steady_state_clean():
    f = jax.jit(lambda x: x * 3.0)
    f(jnp.ones(5))
    with recompile_guard("steady") as g:
        for _ in range(4):
            f(jnp.ones(5))
    assert g.count == 0


def test_recompile_guard_count_mode_never_raises():
    f = jax.jit(lambda x: x * 5.0)
    f(jnp.ones(2))
    with recompile_guard("count", action="count") as g:
        f(jnp.ones(6))
    assert g.count >= 1


def test_recompile_guard_does_not_mask_exceptions():
    f = jax.jit(lambda x: x + 1.0)
    f(jnp.ones(2))
    with pytest.raises(RuntimeError, match="original"):
        with recompile_guard("raise-through") as g:
            f(jnp.ones(7))
            raise RuntimeError("original failure")
    assert g.count >= 1  # the delta is still recorded


def test_session_steady_state_under_guard():
    from repro.api import build_session
    from repro.core.mlmc import MLMCConfig
    from repro.core.robust_train import DynaBROConfig
    from repro.core.scenarios import make_quadratic_task
    from repro.core.switching import get_switcher
    from repro.optim.optimizers import sgd

    task = make_quadratic_task()
    cfg = DynaBROConfig(
        mlmc=MLMCConfig(T=16, m=5, V=3.0, kappa=1.0, j_cap=2),
        aggregator="cwmed",
        delta=0.4,
        attack="sign_flip",
    )
    sess = build_session(
        cfg,
        task,
        switcher=get_switcher("periodic", 5, n_byz=2, K=4, seed=0),
        opt=sgd(2e-2),
        seed=0,
        guard_recompiles=True,
    )
    p1, _, _ = sess.run(16)  # warmup: records the segment signature
    p2, _, _ = sess.run(16)  # steady state: guarded, must not recompile
    np.testing.assert_array_equal(np.asarray(p1["x"]), np.asarray(p2["x"]))


def test_nan_tripwire():
    assert_all_finite({"x": np.ones(3)}, "fine")
    with pytest.raises(FloatingPointError, match="non-finite"):
        assert_all_finite({"x": np.array([1.0, np.inf])}, "agg")
    with pytest.raises(FloatingPointError):
        maybe_assert_finite({"x": np.array([np.nan])}, "agg", enabled=True)
    maybe_assert_finite({"x": np.array([np.nan])}, "agg", enabled=False)
    assert_all_finite({"i": np.array([1, 2], np.int64)}, "ints are exempt")


# ----------------------------------------------- fixes the pass motivated


def test_bernoulli_cap_exact_boundary():
    from repro.core.switching import get_switcher

    # int(0.3 * 10) == 2 under f64 truncation; the exact product is 3 — the
    # old cap ran one Byzantine worker short of δmax·m at these boundaries
    assert get_switcher("bernoulli", 10, p=0.3, D=2, delta_max=0.3).cap == 3
    assert get_switcher("bernoulli", 30, p=0.3, D=2, delta_max=0.1).cap == 3


def test_bernoulli_cap_parity_off_boundary():
    from repro.core.switching import Bernoulli

    # away from exact boundaries the nudged floor equals the old int()
    # truncation, so masks/schedules are bitwise-unchanged there
    for dm, m in [(0.25, 9), (0.3, 9), (0.2, 7), (0.45, 16), (0.5, 11)]:
        new = Bernoulli(m, p=0.2, D=2, delta_max=dm, seed=1)
        assert new.cap == int(dm * m), (dm, m)
        old = Bernoulli(m, p=0.2, D=2, delta_max=dm, seed=1)
        old.cap = int(dm * m)  # the pre-fix formula
        np.testing.assert_array_equal(new.mask_schedule(64), old.mask_schedule(64))


def test_bernoulli_schedule_respects_exact_cap():
    from repro.core.switching import get_switcher

    sched = get_switcher(
        "bernoulli", 10, p=0.9, D=8, delta_max=0.3, seed=3
    ).mask_schedule(128)
    simul = sched.sum(axis=-1)
    assert simul.max() == 3  # reaches the exact cap (old code topped out at 2)


def test_count_floor_and_capacity_nudges():
    from repro.core.agg_engine import count_ceil, count_floor
    from repro.models.moe import _capacity

    assert count_floor(0.3 * 10) == 3
    assert count_floor(2.9) == 2
    assert count_ceil(0.28 * 25) == 7
    # capacity = floor(tokens·k·factor/E), immune to representation error
    assert _capacity(64, 2, 1.25, 8) == 20
    assert _capacity(10, 1, 0.3, 1) == 3
    assert _capacity(1, 1, 0.1, 64) == 1  # floor clamps at 1
