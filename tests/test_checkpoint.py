"""``checkpoint.checkpoint`` round-trips, integrated with the scan drivers.

The contract that matters for long sweeps: a mid-run ``(params, opt_state)``
scan carry saved to disk and restored must resume to the *bitwise* same
trajectory as an uninterrupted run — the schedules are precomputed from the
seed (DESIGN.md §5), so checkpoint fidelity is the only thing that could
break resumption.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import load_checkpoint, save_checkpoint
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import (
    DynaBROConfig, _batch_schedule, _level_plan, _mask_schedule,
    _np_prng_keys, make_dynabro_scan_fn, run_dynabro_scan,
)
from repro.core.scenarios import make_quadratic_task
from repro.core.switching import get_switcher
from repro.optim.optimizers import adagrad_norm

TASK = make_quadratic_task()
M, T, SEED = 9, 16, 3


def _cfg():
    return DynaBROConfig(mlmc=MLMCConfig(T=T, m=M, V=3.0, kappa=1.0, j_cap=2),
                         aggregator="cwmed", delta=0.45, attack="sign_flip")


def _tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert jnp.asarray(x).dtype == jnp.asarray(y).dtype


def test_carry_roundtrip_preserves_values_and_dtypes(tmp_path):
    """A (params, opt_state) carry — nested dict + bare-scalar opt state —
    survives save/load bitwise with dtypes intact."""
    carry = ({"x": jnp.asarray([1.5, -2.25], jnp.float32),
              "c": jnp.asarray([3], jnp.int32)},
             jnp.asarray(7.125, jnp.float32))
    path = str(tmp_path / "carry")
    save_checkpoint(path, carry, step=5)
    restored = load_checkpoint(path, like=carry)
    _tree_equal(carry, restored)


def test_load_rejects_shape_mismatch(tmp_path):
    path = str(tmp_path / "bad")
    save_checkpoint(path, {"x": jnp.zeros((3,))})
    with pytest.raises(AssertionError):
        load_checkpoint(path, like={"x": jnp.zeros((4,))})


def test_scan_resume_from_checkpoint_matches_uninterrupted(tmp_path):
    """Run the compiled driver's first 8 rounds, checkpoint the carry,
    restore it, run the tail — the resumed final params match an
    uninterrupted run_dynabro_scan bitwise. The optimizer is adagrad_norm,
    whose accumulated squared-norm state makes the tail depend on the
    restored opt state, so a dropped or corrupted opt state would show."""
    cfg = _cfg()
    opt = adagrad_norm(2e-2)
    sampler = TASK.make_sampler(M)
    switcher = get_switcher("periodic", M, n_byz=3, K=5, seed=SEED)

    # the reference: one uninterrupted compiled run
    scan_fn = make_dynabro_scan_fn(TASK.grad_fn, cfg, opt)
    p_full, logs_full, _ = run_dynabro_scan(
        TASK.grad_fn, TASK.params0, opt, cfg, switcher, sampler, T,
        seed=SEED, scan_fn=scan_fn)

    # the same schedules the driver precomputes (seeded, DESIGN.md §5)
    levels, ns, n_max = _level_plan(cfg, np.random.default_rng(SEED), T)
    masks = _mask_schedule(switcher, T, n_max, ns)
    keys = _np_prng_keys(SEED * 100_003 + np.arange(T, dtype=np.int64))

    def seg(carry, a, b):
        batches = _batch_schedule(sampler, list(zip(range(a, b), ns[a:b])),
                                  n_max)
        xs = (jnp.asarray(levels[a:b]), batches, jnp.asarray(masks[a:b]),
              jnp.asarray(keys[a:b]))
        return scan_fn(carry, xs)[0]

    half = seg((TASK.params0, opt.init(TASK.params0)), 0, T // 2)
    path = str(tmp_path / "mid_run.npz")
    save_checkpoint(path, half, step=T // 2)
    restored = load_checkpoint(path, like=half)
    _tree_equal(half, restored)  # save/load itself is bitwise

    resumed = seg(restored, T // 2, T)
    np.testing.assert_array_equal(np.asarray(resumed[0]["x"]),
                                  np.asarray(p_full["x"]))
    assert len(logs_full) == T
