"""Parity suite for the compiled ``lax.scan`` drivers (DESIGN.md §5).

Contract: ``run_dynabro_scan`` / ``run_momentum_scan`` are drop-ins for the
legacy Python-loop drivers — same level/mask/key/batch schedules, same
numerics round for round. The legacy drivers are the reference; every test
here runs both and compares final params, per-round logs, and eval traces.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks

from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import (
    DynaBROConfig, run_dynabro, run_dynabro_scan, run_momentum,
    run_momentum_scan,
)
from repro.core.scenarios import (
    format_table, make_quadratic_task, run_matrix, scenario_grid,
)
from repro.core.switching import Switcher, get_switcher
from repro.optim.optimizers import adagrad_norm, sgd

TASK = make_quadratic_task()
T = 64
M = 9


def _cfg(agg="cwmed", attack="sign_flip", use_mlmc=True, m=M, **akw):
    return DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=m, V=3.0, option=2 if agg == "mfm" else 1,
                        kappa=1.0),
        aggregator=agg, delta=0.45, attack=attack,
        attack_kwargs=akw or None, use_mlmc=use_mlmc)


def _sw(m=M):
    return get_switcher("periodic", m, n_byz=4, K=10)


def _run_both(cfg, m=M, seed=3, opt=lambda: sgd(2e-2), sampler=None,
              eval_every=0, **scan_kw):
    sampler = sampler or TASK.make_sampler(m)
    ev = (lambda p, t: {"f": TASK.objective(p)}) if eval_every else None
    ref = run_dynabro(TASK.grad_fn, TASK.params0, opt(), cfg, _sw(m), sampler,
                      T, seed=seed, eval_fn=ev, eval_every=eval_every)
    new = run_dynabro_scan(TASK.grad_fn, TASK.params0, opt(), cfg, _sw(m),
                           sampler, T, seed=seed, eval_fn=ev,
                           eval_every=eval_every, **scan_kw)
    return ref, new


def _assert_logs_equal(l1, l2):
    assert len(l1) == len(l2) == T
    assert [l.level for l in l1] == [l.level for l in l2]
    assert [l.failsafe_ok for l in l1] == [l.failsafe_ok for l in l2]
    assert [l.n_byz for l in l1] == [l.n_byz for l in l2]
    assert [l.cost for l in l1] == [l.cost for l in l2]


@pytest.mark.parametrize("use_mlmc,agg,attack", [
    (True, "cwmed", "sign_flip"),
    (True, "cwtm", "ipm"),
    (True, "mfm", "alie"),
    (True, "cwmed", "random"),
    (False, "cwmed", "sign_flip"),
    (False, "cwtm", "shift"),
])
def test_scan_parity_quadratic(use_mlmc, agg, attack):
    (p1, l1, _), (p2, l2, _) = _run_both(_cfg(agg, attack, use_mlmc))
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]),
                               rtol=1e-6, atol=1e-7)
    _assert_logs_equal(l1, l2)
    assert {l.level for l in l1} >= ({1, 2} if use_mlmc else {0})


def test_scan_parity_adagrad_norm_and_evals():
    cfg = _cfg("mfm", "sign_flip")
    (p1, l1, e1), (p2, l2, e2) = _run_both(
        cfg, opt=lambda: adagrad_norm(1.0), eval_every=16)
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]),
                               rtol=1e-6, atol=1e-7)
    _assert_logs_equal(l1, l2)
    assert [t for t, _ in e1] == [t for t, _ in e2] == [16, 32, 48, 64]
    for (_, a), (_, b) in zip(e1, e2):
        np.testing.assert_allclose(a["f"], b["f"], rtol=1e-6, atol=1e-7)


def test_scan_chunking_is_invisible():
    (_, _, _), (p0, l0, _) = _run_both(_cfg())
    _, (p16, l16, _) = _run_both(_cfg(), chunk=16)
    np.testing.assert_array_equal(np.asarray(p0["x"]), np.asarray(p16["x"]))
    _assert_logs_equal(l0, l16)


def test_eval_cadence_nonaligned_chunk():
    """Satellite-3 lock (PR 7): with chunk=7 no chunk boundary aligns with
    eval_every=16, so every eval point sits on a mixed segment bound — the
    scan driver must still evaluate at exactly the legacy rounds, with the
    legacy values."""
    (p1, l1, e1), (p2, l2, e2) = _run_both(_cfg(), eval_every=16, chunk=7)
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]),
                               rtol=1e-6, atol=1e-7)
    _assert_logs_equal(l1, l2)
    assert [t for t, _ in e1] == [t for t, _ in e2] == [16, 32, 48, 64]
    for (_, a), (_, b) in zip(e1, e2):
        np.testing.assert_allclose(a["f"], b["f"], rtol=1e-6, atol=1e-7)


def test_momentum_eval_cadence_nonaligned_chunk():
    m = 3
    cfg = _cfg("cwmed", "shift", m=m, v=3.0)
    sampler = TASK.make_sampler(m)

    def ev(p, t):
        return {"f": TASK.objective(p)}

    def sw():
        return get_switcher("periodic", m, n_byz=1, K=10)
    p1, e1 = run_momentum(TASK.grad_fn, TASK.params0, cfg, sw(), sampler, T,
                          lr=2e-2, beta=0.9, seed=1, eval_fn=ev,
                          eval_every=16)
    p2, e2 = run_momentum_scan(TASK.grad_fn, TASK.params0, cfg, sw(), sampler,
                               T, lr=2e-2, beta=0.9, seed=1, eval_fn=ev,
                               eval_every=16, chunk=7)
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]),
                               rtol=1e-6, atol=1e-7)
    assert [t for t, _ in e1] == [t for t, _ in e2] == [16, 32, 48, 64]
    for (_, a), (_, b) in zip(e1, e2):
        np.testing.assert_allclose(a["f"], b["f"], rtol=1e-6, atol=1e-7)


def test_scan_microbatch_parity():
    """Microbatched streaming (DESIGN.md §9) vs the legacy driver: identical
    schedules and logs; params within fp tolerance (the three-accumulator
    summation order differs from the stacked slices by design, so bitwise
    equality is not the contract here)."""
    (p1, l1, _), (p2, l2, _) = _run_both(_cfg("cwtm"), microbatch=True)
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]),
                               rtol=1e-5, atol=1e-6)
    _assert_logs_equal(l1, l2)


def test_scan_microbatch_prebuilt_tag_mismatch():
    from repro.core.robust_train import make_dynabro_scan_fn

    cfg = _cfg()
    fn = make_dynabro_scan_fn(TASK.grad_fn, cfg, sgd(2e-2), microbatch=True)
    with pytest.raises(ValueError, match="microbatch"):
        run_dynabro_scan(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, _sw(),
                         TASK.make_sampler(M), T, scan_fn=fn)


def test_beyond_cap_cost_parity_all_drivers():
    """Beyond-cap rounds (J > j_max: correction dropped, one unit batch per
    worker) must be sampled and logged with cost 1 — the ``mlmc.round_cost``
    contract — identically by the legacy, scan and sweep drivers. j_cap=1
    makes half of all rounds beyond-cap."""
    from repro.core.mlmc import round_cost
    from repro.core.robust_train import run_dynabro_scan_sweep

    cfg = DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=M, V=3.0, kappa=1.0, j_cap=1),
        aggregator="cwmed", delta=0.45, attack="sign_flip")
    (p1, l1, _), (p2, l2, _) = _run_both(cfg)
    _assert_logs_equal(l1, l2)
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]),
                               rtol=1e-6, atol=1e-7)
    beyond = [l for l in l1 if l.level > cfg.mlmc.j_max]
    assert beyond  # P(J=2) = 1/2 per round: T=64 rounds surely sample it
    assert all(l.cost == 1 for l in beyond)
    in_cap = [l for l in l1 if l.level == 1]
    assert in_cap and all(l.cost == 1 + 1 + 2 for l in in_cap)
    assert [l.cost for l in l1] == [round_cost(l.level, cfg.mlmc.j_max)
                                    for l in l1]
    # the vmapped sweep logs the same rounds lane for lane
    [(p3, l3), (p4, l4)] = run_dynabro_scan_sweep(
        TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, [_sw(), _sw()],
        TASK.make_sampler(M), T, seed=3)
    assert l3 == l1 and l4 == l1
    np.testing.assert_allclose(np.asarray(p3["x"]), np.asarray(p1["x"]),
                               rtol=1e-6, atol=1e-7)


def test_scan_parity_within_round_switching():
    """Identities flipping *within* a round exercise the generic
    ``mask_schedule`` path and the per-k attack keys."""

    m = 8

    class WithinRound(Switcher):
        def __init__(self):
            super().__init__(m)

        def mask(self, t):
            return np.zeros(m, bool)

        def within_round(self, t, k):
            mk = np.zeros(m, bool)
            if k % 2 == 1:  # half the computations Byzantine for half the workers
                mk[:4] = True
            return mk

    cfg = _cfg("cwmed", "shift", m=m, v=200.0)
    sampler = TASK.make_sampler(m)
    p1, l1, _ = run_dynabro(TASK.grad_fn, TASK.params0, sgd(1e-2), cfg,
                            WithinRound(), sampler, T, seed=5)
    p2, l2, _ = run_dynabro_scan(TASK.grad_fn, TASK.params0, sgd(1e-2), cfg,
                                 WithinRound(), sampler, T, seed=5)
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]),
                               rtol=1e-6, atol=1e-7)
    _assert_logs_equal(l1, l2)
    assert any(l.level >= 1 and not l.failsafe_ok for l in l1)


def test_scan_parity_unvectorizable_sampler():
    """A sampler that concretizes t cannot be vmapped; the driver must fall
    back to the per-round loop and still match the reference bit for bit."""
    m = 5

    def np_sampler(t, n):
        rng = np.random.default_rng(int(t) * 1000 + n)
        keys = rng.integers(0, 2 ** 31, size=(m, n, 2), dtype=np.int64)
        return jnp.asarray(keys.astype(np.uint32))

    cfg = _cfg(m=m)
    sw1, sw2 = _sw(m), _sw(m)
    p1, l1, _ = run_dynabro(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg, sw1,
                            np_sampler, T, seed=2)
    p2, l2, _ = run_dynabro_scan(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
                                 sw2, np_sampler, T, seed=2)
    np.testing.assert_array_equal(np.asarray(p1["x"]), np.asarray(p2["x"]))
    _assert_logs_equal(l1, l2)


@pytest.mark.parametrize("switcher,kw", [
    ("static", {"n_byz": 4}),
    ("bernoulli", {"p": 0.1, "D": 5, "delta_max": 0.5}),
])
def test_scan_parity_other_switchers(switcher, kw):
    cfg = _cfg("cwtm", "sign_flip")
    sampler = TASK.make_sampler(M)
    p1, l1, _ = run_dynabro(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
                            get_switcher(switcher, M, **kw), sampler, T)
    p2, l2, _ = run_dynabro_scan(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
                                 get_switcher(switcher, M, **kw), sampler, T)
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]),
                               rtol=1e-6, atol=1e-7)
    _assert_logs_equal(l1, l2)


def test_scan_parity_stateful_sampler_opt_out():
    """A sampler with hidden per-call state cannot survive the vectorized
    probe; vectorize_batches=False replays the legacy call order exactly."""
    m = 5
    calls_ref, calls_scan = [], []

    def make_stateful(calls):
        def sample(t, n):
            calls.append((t, n))
            keys = jax.random.split(
                jax.random.fold_in(jax.random.PRNGKey(len(calls)), t), m * n)
            return keys.reshape(m, n, *keys.shape[1:])
        return sample

    cfg = _cfg(m=m)
    p1, l1, _ = run_dynabro(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
                            _sw(m), make_stateful(calls_ref), T, seed=2)
    p2, l2, _ = run_dynabro_scan(TASK.grad_fn, TASK.params0, sgd(2e-2), cfg,
                                 _sw(m), make_stateful(calls_scan), T, seed=2,
                                 vectorize_batches=False)
    assert calls_ref == calls_scan  # exactly once per round, in round order
    np.testing.assert_array_equal(np.asarray(p1["x"]), np.asarray(p2["x"]))
    _assert_logs_equal(l1, l2)


def test_scan_drivers_handle_T0_like_legacy():
    cfg = _cfg()
    p, logs, evals = run_dynabro_scan(TASK.grad_fn, TASK.params0, sgd(2e-2),
                                      cfg, _sw(), TASK.make_sampler(M), 0)
    assert logs == [] and evals == []
    np.testing.assert_array_equal(np.asarray(p["x"]),
                                  np.asarray(TASK.params0["x"]))
    p, evals = run_momentum_scan(TASK.grad_fn, TASK.params0, cfg, _sw(),
                                 TASK.make_sampler(M), 0, lr=1e-2, beta=0.9)
    assert evals == []


def test_momentum_scan_parity():
    m = 3
    cfg = _cfg("cwmed", "shift", m=m, v=3.0)
    sampler = TASK.make_sampler(m)
    def ev(p, t):
        return {"f": TASK.objective(p)}

    def sw():
        return get_switcher("momentum_tailored", m, alpha=0.05)
    p1, e1 = run_momentum(TASK.grad_fn, TASK.params0, cfg, sw(), sampler, T,
                          lr=2e-2, beta=0.95, seed=1, eval_fn=ev,
                          eval_every=32)
    p2, e2 = run_momentum_scan(TASK.grad_fn, TASK.params0, cfg, sw(), sampler,
                               T, lr=2e-2, beta=0.95, seed=1, eval_fn=ev,
                               eval_every=32)
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]),
                               rtol=1e-6, atol=1e-7)
    assert [t for t, _ in e1] == [t for t, _ in e2]
    for (_, a), (_, b) in zip(e1, e2):
        np.testing.assert_allclose(a["f"], b["f"], rtol=1e-6, atol=1e-7)


# ------------------------------------------------------------- MLP config


@pytest.mark.parametrize("use_mlmc,agg", [
    (True, "cwmed"),
    (True, "cwtm"),
    (False, "cwmed"),
])
def test_scan_parity_mlp(use_mlmc, agg):
    """Parity on the MLP classifier config (benchmarks harness of the paper's
    Section 6 experiments) over 64 rounds."""
    from benchmarks._clf import make_task

    m = 6
    params0, grad_fn, sampler, _ = make_task(m, unit_batch=8, seed=1)
    cfg = DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=m, V=3.0, kappa=1.0, j_cap=3),
        aggregator=agg, delta=0.34, attack="sign_flip", use_mlmc=use_mlmc)
    def sw():
        return get_switcher("periodic", m, n_byz=2, K=10)
    p1, l1, _ = run_dynabro(grad_fn, params0, sgd(5e-2), cfg, sw(), sampler,
                            T, seed=7)
    p2, l2, _ = run_dynabro_scan(grad_fn, params0, sgd(5e-2), cfg, sw(),
                                 sampler, T, seed=7)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    _assert_logs_equal(l1, l2)


# ------------------------------------------------------------- scenarios


def test_scenario_matrix_runner():
    grid = scenario_grid(
        ["sign_flip", "ipm"],
        [("periodic", {"n_byz": 3, "K": 10})],
        ["mean", "cwmed"])
    assert len(grid) == 4
    rows = run_matrix(TASK, grid, m=M, T=40, V=3.0, delta=3 / M + 0.01,
                      j_cap=3, seed=0)
    assert len(rows) == 4
    for r in rows:
        assert {"attack", "switcher", "aggregator", "final", "failsafe_trips",
                "wall_s", "cost"} <= set(r)
        assert np.isfinite(r["final"])
    by = {(r["attack"], r["aggregator"]): r["final"] for r in rows}
    # robust aggregation survives sign_flip where the mean does not
    assert by[("sign_flip", "cwmed")] < by[("sign_flip", "mean")]
    table = format_table(rows)
    assert "cwmed" in table and "sign_flip" in table


def test_scenario_runner_matches_legacy_driver():
    grid = scenario_grid(["sign_flip"], [("static", {"n_byz": 3})], ["cwmed"])
    row_scan = run_matrix(TASK, grid, m=M, T=40, V=3.0, driver="scan")[0]
    row_ref = run_matrix(TASK, grid, m=M, T=40, V=3.0, driver="legacy")[0]
    np.testing.assert_allclose(row_scan["final"], row_ref["final"],
                               rtol=1e-6, atol=1e-7)
    assert row_scan["cost"] == row_ref["cost"]
