import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no xla_force_host_platform_device_count here — smoke tests must see
# one device. Multi-device tests (Mode B sharding) run in subprocesses that
# set their own XLA_FLAGS.
