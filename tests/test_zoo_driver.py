"""Unified Mode-A/Mode-B model-zoo driver (PR 7 tentpole, DESIGN.md §9):
real reduced architectures through ``run_dynabro_scan`` with a 2-axis
``(workers, 'model')`` mesh, FSDP ``param_specs`` and microbatch streaming."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig, run_dynabro_scan
from repro.core.switching import get_switcher
from repro.launch.mesh import make_worker_mesh
from repro.launch.sharding import plan_params
from repro.models.zoo import make_zoo_task
from repro.optim.optimizers import sgd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _dcfg(T, m, j_cap=2):
    return DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=m, V=3.0, kappa=1.0, j_cap=j_cap),
        aggregator="cwtm", delta=0.3, attack="sign_flip")


def _run_zoo(task, T, m, j_cap, **kw):
    return run_dynabro_scan(task.grad_fn, task.params0, sgd(0.05),
                            _dcfg(T, m, j_cap), get_switcher(
                                "periodic", m, n_byz=1, K=max(2, T // 4)),
                            task.make_sampler(m), T, seed=3, **kw)


def _assert_trees_equal(p1, p2, bitwise=True):
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        a, b = np.asarray(a), np.asarray(b)
        if bitwise:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_zoo_transformer_microbatch_parity_mesh11():
    """Small transformer: the (1, 1) GSPMD mesh must be bitwise-identical to
    mesh=None (all constraints skipped -> identical traced graph)."""
    task, cfg = make_zoo_task("smollm-360m", seq_len=8, d_model=32)
    T, m = 8, 4
    mesh = make_worker_mesh(1, model=1)
    specs, _ = plan_params(cfg, mesh, fsdp=True, dtype=jnp.float32)
    p_u, l_u, _ = _run_zoo(task, T, m, 1, microbatch=True)
    p_s, l_s, _ = _run_zoo(task, T, m, 1, microbatch=True, mesh=mesh,
                           param_specs=specs)
    _assert_trees_equal(p_u, p_s, bitwise=True)
    assert [l.level for l in l_u] == [l.level for l in l_s]
    assert [l.failsafe_ok for l in l_u] == [l.failsafe_ok for l in l_s]


@pytest.mark.slow
def test_zoo_transformer_and_moe_T32():
    """The tentpole acceptance run: reduced transformer AND MoE train T=32
    rounds through run_dynabro_scan(mesh=...) with microbatching, bitwise
    against the unsharded driver on the parity-contract mesh."""
    T, m = 32, 4
    for arch in ("smollm-360m", "qwen2-moe-a2.7b"):
        task, cfg = make_zoo_task(arch, seq_len=16, d_model=64)
        mesh = make_worker_mesh(1, model=1)
        specs, _ = plan_params(cfg, mesh, fsdp=True, dtype=jnp.float32)
        p_u, l_u, _ = _run_zoo(task, T, m, 2, microbatch=True)
        p_s, l_s, _ = _run_zoo(task, T, m, 2, microbatch=True, mesh=mesh,
                               param_specs=specs)
        _assert_trees_equal(p_u, p_s, bitwise=True)
        assert len(l_s) == T
        assert np.isfinite(task.objective(p_s))


@pytest.mark.slow
def test_zoo_sharded_multidevice_parity():
    """4-device (2 workers x 2 model) subprocess: the sharded microbatched
    transformer run must match the unsharded microbatched run (the §9 parity
    contract — allclose, not bitwise: GSPMD partitions the reductions)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.mlmc import MLMCConfig
        from repro.core.robust_train import DynaBROConfig, run_dynabro_scan
        from repro.core.switching import get_switcher
        from repro.launch.mesh import make_worker_mesh
        from repro.launch.sharding import plan_params
        from repro.models.zoo import make_zoo_task
        from repro.optim.optimizers import sgd

        T, m = 8, 4
        task, cfg = make_zoo_task("smollm-360m", seq_len=16, d_model=64)
        dcfg = DynaBROConfig(
            mlmc=MLMCConfig(T=T, m=m, V=3.0, kappa=1.0, j_cap=2),
            aggregator="cwtm", delta=0.3, attack="sign_flip")
        mesh = make_worker_mesh(2, model=2)
        assert tuple(mesh.axis_names) == ("workers", "model")
        specs, _ = plan_params(cfg, mesh, fsdp=True, dtype=jnp.float32)

        def run(**kw):
            return run_dynabro_scan(
                task.grad_fn, task.params0, sgd(0.05), dcfg,
                get_switcher("periodic", m, n_byz=1, K=4),
                task.make_sampler(m), T, seed=3, microbatch=True, **kw)

        p_u, l_u, _ = run()
        p_s, l_s, _ = run(mesh=mesh, param_specs=specs)
        for a, b in zip(jax.tree.leaves(p_u), jax.tree.leaves(p_s)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        assert [l.level for l in l_u] == [l.level for l in l_s]
        assert [l.failsafe_ok for l in l_u] == [l.failsafe_ok for l in l_s]
        print("OK zoo multidevice parity")
    """ % SRC)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-4000:] + "\n" + r.stderr[-4000:]
    assert "OK zoo multidevice parity" in r.stdout
