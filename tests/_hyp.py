"""Optional-import shim for hypothesis.

The CI/dev container does not ship ``hypothesis``; importing it at module
scope used to make ``test_aggregators.py`` and ``test_models.py`` fail at
collection. When hypothesis is present we re-export the real API unchanged.
When it is absent we substitute a tiny deterministic fallback: each strategy
draws from a seeded RNG and ``@given`` re-runs the test body for a handful of
draws — weaker than real shrinking/edge-case search, but it keeps the same
properties exercised everywhere.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import numpy as _np

    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: options[int(rng.integers(len(options)))])

    st = _St()

    def settings(*_a, **_kw):  # accepts and ignores max_examples/deadline/...
        return lambda fn: fn

    def given(*strategies):
        def deco(fn):
            import inspect
            params = list(inspect.signature(fn).parameters)
            strat_names = params[len(params) - len(strategies):]

            def run(**kwargs):  # non-strategy args (parametrize) arrive by kw
                rng = _np.random.default_rng(
                    _np.frombuffer(fn.__qualname__.encode(), dtype=_np.uint8))
                for _ in range(_FALLBACK_EXAMPLES):
                    draws = {n: s.example(rng)
                             for n, s in zip(strat_names, strategies)}
                    fn(**kwargs, **draws)

            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            run.__dict__.update(fn.__dict__)  # carries pytestmark
            # pytest must see only the non-strategy params (fixtures/parametrize)
            run.__signature__ = inspect.Signature(
                [inspect.Parameter(n, inspect.Parameter.POSITIONAL_OR_KEYWORD)
                 for n in params[:len(params) - len(strategies)]])
            return run
        return deco
