"""Property tests for the robust aggregation rules."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or offline fallback

from repro.core.aggregators import (
    CWMed, CWTM, GeoMed, Krum, MFM, Mean, get_aggregator,
    pairwise_sqdists, tree_stack_to_mat, mat_to_tree,
)

AGGS = ["mean", "cwmed", "cwtm", "krum", "geomed", "nnm+cwmed", "nnm+cwtm"]
ROBUST_AGGS = [a for a in AGGS if a != "mean"]


def _mk(m, d, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(m, d)).astype(np.float32))


@pytest.mark.parametrize("name", AGGS)
def test_agreement_matrix_vs_tree(name):
    """Tree API must agree with the flat matrix API (global geometry)."""
    x = _mk(9, 24)
    agg = get_aggregator(name, delta=0.25)
    flat = agg(x)
    tree = {"a": x[:, :10].reshape(9, 2, 5), "b": x[:, 10:]}
    out = agg.tree(tree)
    got = jnp.concatenate([out["a"].reshape(-1), out["b"].reshape(-1)])
    np.testing.assert_allclose(np.asarray(flat), np.asarray(got), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name", AGGS)
def test_identical_inputs_fixed_point(name):
    """A(g, g, ..., g) == g for every rule (consistency)."""
    g = _mk(1, 33)[0]
    x = jnp.tile(g[None], (7, 1))
    agg = get_aggregator(name, delta=0.25)
    np.testing.assert_allclose(np.asarray(agg(x)), np.asarray(g), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(5, 12), st.integers(1, 30), st.floats(10.0, 1e4),
       st.integers(0, 10_000))
@pytest.mark.parametrize("name", ROBUST_AGGS)
def test_robustness_bounded_by_honest_spread(name, m, d, atk_scale, seed):
    """Definition 3.2 flavor: with < m/2 outliers at arbitrary magnitude, the
    aggregation error vs the honest mean stays bounded by the honest spread
    (it must NOT scale with the attack magnitude)."""
    rng = np.random.default_rng(seed)
    n_byz = max(1, int(0.25 * m))  # stay clearly below the 1/2 breakdown point
    honest = rng.normal(size=(m - n_byz, d))
    byz = rng.normal(size=(n_byz, d)) * atk_scale + atk_scale
    x = jnp.asarray(np.concatenate([honest, byz]).astype(np.float32))
    agg = get_aggregator(name, delta=max(n_byz / m, 0.26))
    out = np.asarray(agg(x))
    hm = honest.mean(0)
    spread = np.sqrt(((honest - hm) ** 2).sum(1)).max() + 1e-6
    err = np.sqrt(((out - hm) ** 2).sum())
    assert err <= 6.0 * spread + 1e-3, (name, err, spread)


def test_mean_not_robust():
    """Sanity: the mean IS broken by a single Byzantine (Blanchard et al.)."""
    x = _mk(8, 4).at[0].set(1e6)
    assert float(jnp.abs(Mean()(x)).max()) > 1e4


def test_cwmed_coordinatewise_median():
    x = _mk(7, 13)
    np.testing.assert_allclose(np.asarray(CWMed()(x)),
                               np.median(np.asarray(x), axis=0), rtol=1e-6)


def test_cwtm_trims_extremes():
    x = _mk(10, 5)
    x = x.at[0].set(1e9).at[1].set(-1e9)
    out = np.asarray(CWTM(delta=0.2)(x))
    assert np.abs(out).max() < 10.0


def test_krum_selects_real_input():
    x = _mk(9, 6)
    x = x.at[0].set(500.0)
    out = np.asarray(Krum(delta=0.2)(x))
    dists = np.abs(np.asarray(x) - out[None]).sum(1)
    assert dists.min() < 1e-6  # output is one of the inputs
    assert not np.allclose(out, np.asarray(x[0]))  # and not the Byzantine one


def test_geomed_minimizes_distance_sum():
    x = _mk(9, 4)
    gm = np.asarray(GeoMed(iters=64)(x))
    xn = np.asarray(x)

    def cost(z):
        return np.sqrt(((xn - z[None]) ** 2).sum(1)).sum()

    c = cost(gm)
    for _ in range(50):  # random perturbations should not improve
        assert cost(gm + np.random.default_rng(_).normal(size=4) * 0.05) >= c - 1e-3


# ---------------------------------------------------------------- MFM


def test_mfm_clean_equals_mean_dirty_filtered():
    rng = np.random.default_rng(3)
    honest = rng.normal(size=(7, 16)) * 0.1
    x = jnp.asarray(np.concatenate([honest, honest[:1] + 100.0]).astype(np.float32))
    out = np.asarray(MFM(tau=2.0)(x))
    np.testing.assert_allclose(out, honest.mean(0), atol=0.25)


def test_mfm_no_majority_outputs_zero():
    """Algorithm 3: if no vector has a majority within tau/2, output 0."""
    x = jnp.asarray((np.arange(6)[:, None] * 100.0 * np.ones((6, 3))).astype(np.float32))
    out = np.asarray(MFM(tau=1.0)(x))
    np.testing.assert_allclose(out, 0.0)


def test_mfm_not_kappa_robust_construction():
    """Appendix F.1: zero honest variance but nonzero aggregation error."""
    tau = 4.0
    d = 8
    nabla = np.zeros(d, np.float32)
    honest = np.tile(nabla, (5, 1))
    v = np.ones(d, np.float32) / np.sqrt(d)
    byz = np.tile(nabla + 0.75 * tau * v, (3, 1))
    x = jnp.asarray(np.concatenate([honest, byz]))
    out = np.asarray(MFM(tau=tau)(x))
    # honest "variance" is 0, yet the error is strictly positive => not (δ,κ)-robust
    assert np.linalg.norm(out - nabla) > 0.1


# ---------------------------------------------------------------- helpers


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 9), st.integers(1, 40))
def test_pairwise_matches_numpy(m, d):
    x = _mk(m, d, seed=m * 100 + d)
    got = np.asarray(pairwise_sqdists(x))
    xn = np.asarray(x)
    want = ((xn[:, None] - xn[None]) ** 2).sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_tree_roundtrip():
    tree = {"w": _mk(4, 6).reshape(4, 2, 3), "b": _mk(4, 2, seed=1)}
    mat = tree_stack_to_mat(tree)
    assert mat.shape == (4, 8)
    back = mat_to_tree(mat[0], tree)
    np.testing.assert_allclose(np.asarray(back["w"]), np.asarray(tree["w"][0]))
