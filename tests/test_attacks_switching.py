"""Attacks (Appendix J) and identity-switching strategies (Section 6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or offline fallback

from repro.core import attacks as atk
from repro.core.switching import Bernoulli, MomentumTailored, Periodic, Static, get_switcher


def _stack(m=8, d=5, seed=0):
    return {"g": jnp.asarray(np.random.default_rng(seed).normal(size=(m, d)).astype(np.float32))}


def test_sign_flip():
    s = _stack()
    mask = jnp.array([True] + [False] * 7)
    out = atk.sign_flip(s, mask)
    np.testing.assert_allclose(np.asarray(out["g"][0]), -np.asarray(s["g"][0]))
    np.testing.assert_allclose(np.asarray(out["g"][1:]), np.asarray(s["g"][1:]))


def test_ipm_sends_scaled_negative_mean():
    s = _stack()
    mask = jnp.array([True, True] + [False] * 6)
    out = atk.ipm(s, mask, eps=0.1)
    hm = np.asarray(s["g"][2:]).mean(0)
    np.testing.assert_allclose(np.asarray(out["g"][0]), -0.1 * hm, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out["g"][1]), -0.1 * hm, rtol=1e-5)


def test_alie_within_noise_envelope():
    s = _stack(m=20, d=3, seed=2)
    mask = jnp.asarray([True] * 4 + [False] * 16)
    out = atk.alie(s, mask, z=1.0)
    h = np.asarray(s["g"][4:])
    mu, sd = h.mean(0), h.std(0)
    np.testing.assert_allclose(np.asarray(out["g"][0]), mu - 1.0 * sd, rtol=1e-4, atol=1e-5)


def test_attack_registry_none_identity():
    s = _stack()
    out = atk.get_attack("none")(s, jnp.ones(8, bool))
    np.testing.assert_allclose(np.asarray(out["g"]), np.asarray(s["g"]))


def test_alie_auto_z_matches_baruch_prescription():
    """z=None derives z from (m, n_byz): with m=20, b=4 the attacker needs
    s = ⌊m/2+1⌋ − b = 7 supporters, so z = Φ⁻¹((20−4−7)/16) = Φ⁻¹(9/16)."""
    s = _stack(m=20, d=3, seed=2)
    mask = jnp.asarray([True] * 4 + [False] * 16)
    z_want = float(jax.scipy.special.ndtri(9.0 / 16.0))
    np.testing.assert_allclose(float(atk.alie_auto_z(mask)), z_want, rtol=1e-6)
    out = atk.alie(s, mask, z=None)
    h = np.asarray(s["g"][4:])
    mu, sd = h.mean(0), h.std(0)
    np.testing.assert_allclose(np.asarray(out["g"][0]), mu - z_want * sd,
                               rtol=1e-4, atol=1e-5)
    # more Byzantine workers need fewer honest supporters -> a larger shift
    z8 = float(atk.alie_auto_z(jnp.asarray([True] * 8 + [False] * 12)))
    assert z8 > z_want
    # the fixed default is untouched (existing goldens)
    out_def = atk.alie(s, mask)
    np.testing.assert_allclose(np.asarray(out_def["g"][0]),
                               mu - 1.22 * sd, rtol=1e-4, atol=1e-5)


# ------------------------------------------- uniform traced-theta dispatch


THETA_CASES = [
    ("none", {}),
    ("sign_flip", {"scale": 2.0}),
    ("ipm", {"eps": 0.3}),
    ("alie", {"z": 0.9}),
    ("alie", {"z": None}),
    ("random", {"scale": 2.5}),
    ("shift", {"v": 5.0}),
]


def test_uniform_dispatch_matches_kwarg_attacks():
    """attack_switch over the full registry reproduces each kwarg-configured
    attack within the parity tolerance (the switch body is one compiled
    computation, so XLA may FMA-contract where the eager kwarg path runs op
    by op — same contract as the sweep drivers, 1e-6)."""
    s = _mixed_stack(seed=4)
    mask = jnp.asarray([True, False] * 4)
    key = jax.random.PRNGKey(7)
    names = tuple(dict.fromkeys(n for n, _ in THETA_CASES))
    apply_fn = atk.attack_switch(names)
    for name, kw in THETA_CASES:
        want = atk.get_attack(name, **kw)(s, mask, key=key)
        got = apply_fn(jnp.int32(names.index(name)), s, mask, key,
                       jnp.asarray(atk.attack_theta(name, kw)))
        for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7,
                                       err_msg=f"{name} {kw}")


def test_attack_theta_defaults_and_nan_sentinel():
    assert atk.N_PARAMS >= 1
    np.testing.assert_array_equal(atk.attack_theta("sign_flip"),
                                  np.ones(atk.N_PARAMS, np.float32))
    assert float(atk.attack_theta("ipm")[0]) == np.float32(0.1)
    assert np.isnan(atk.attack_theta("alie", {"z": None})[0])
    assert float(atk.attack_theta("alie")[0]) == np.float32(1.22)


def test_attack_theta_rejects_unknown_params():
    with pytest.raises(TypeError, match="bogus"):
        atk.attack_theta("ipm", {"bogus": 1.0})


def test_attack_theta_rejects_none_without_sentinel_support():
    """Only alie's z interprets the NaN sentinel; None anywhere else would
    silently feed NaN gradients on the lane path while the eager kwarg path
    raises — the drop-in contract demands both fail loudly."""
    with pytest.raises(TypeError, match="does not accept None"):
        atk.attack_theta("ipm", {"eps": None})
    with pytest.raises(TypeError, match="does not accept None"):
        atk.attack_theta("sign_flip", {"scale": None})


def test_single_name_attack_switch_skips_the_switch():
    s = _stack()
    mask = jnp.asarray([True] + [False] * 7)
    apply_fn = atk.attack_switch(("sign_flip",))
    got = apply_fn(jnp.int32(0), s, mask, jax.random.PRNGKey(0),
                   jnp.asarray(atk.attack_theta("sign_flip")))
    want = atk.sign_flip(s, mask)
    np.testing.assert_array_equal(np.asarray(got["g"]), np.asarray(want["g"]))


# ------------------------------------------------------------- switching


def test_static_mask_fixed():
    sw = Static(10, 4, seed=1)
    m0 = sw.mask(0)
    assert m0.sum() == 4
    for t in range(50):
        assert (sw.mask(t) == m0).all()


def test_periodic_switches_every_K():
    sw = Periodic(17, 8, K=10, seed=0)
    assert all(sw.mask(t).sum() == 8 for t in range(40))
    m0, m10 = sw.mask(0), sw.mask(10)
    assert (sw.mask(9) == m0).all()
    assert not (m10 == m0).all()  # overwhelmingly likely with 17 choose 8
    sw2 = Periodic(17, 8, K=10, seed=0)
    assert (sw2.mask(25) == sw.mask(25)).all()  # deterministic


def test_bernoulli_caps_fraction_and_duration():
    sw = Bernoulli(25, p=0.05, D=10, delta_max=0.48, seed=0)
    cap = int(0.48 * 25)
    counts = [sw.mask(t).sum() for t in range(500)]
    assert max(counts) <= cap
    assert max(counts) > 0  # attacks do happen
    # durations: once byzantine, stays for D rounds
    m = np.stack([sw.mask(t) for t in range(500)])
    for i in range(25):
        runs = np.diff(np.flatnonzero(np.diff(m[:, i].astype(int)) != 0))
        if len(runs) > 2:
            byz_runs = runs[::2] if m[np.flatnonzero(np.diff(m[:, i].astype(int)))[0] + 1, i] else runs[1::2]
            assert all(r == 10 for r in byz_runs[:-1])
            break


def test_momentum_tailored_single_worker_rotation():
    sw = MomentumTailored(3, alpha=0.1)
    period, third = 10, 3
    masks = [sw.mask(t) for t in range(30)]
    assert all(mk.sum() == 1 for mk in masks)
    # rotates among the three workers, O(sqrt T) switches
    seen = {tuple(mk) for mk in masks}
    assert len(seen) == 3
    assert sw.switch_rounds(300) <= 3 * 0.1 * 300 + 3


def test_switch_rounds_counter():
    sw = Periodic(8, 3, K=25, seed=0)
    assert sw.switch_rounds(100) <= 4


def test_get_switcher_registry():
    for name, kw in [("static", {"n_byz": 2}), ("periodic", {"n_byz": 2, "K": 5}),
                     ("bernoulli", {"p": 0.1, "D": 5, "delta_max": 0.5}),
                     ("momentum_tailored", {"alpha": 0.1})]:
        sw = get_switcher(name, 8, **kw)
        assert sw.mask(0).shape == (8,)


# ------------------------------------------- switching properties (hypothesis)


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 24), st.integers(0, 10), st.integers(0, 1000))
def test_prop_static_fixed_count_and_no_switches(m, seed, T0):
    n_byz = seed % (m + 1)  # any feasible count, including 0 and m
    sw = Static(m, n_byz, seed=seed)
    assert sw.mask(T0).sum() == n_byz
    assert sw.switch_rounds(50) == 0
    for t in (0, 7, T0):
        assert (sw.mask(t) == sw.mask(0)).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 24), st.integers(1, 12), st.integers(0, 10))
def test_prop_periodic_count_and_switch_rounds(m, K, seed):
    n_byz = 1 + seed % (m - 1)
    sw = Periodic(m, n_byz, K=K, seed=seed)
    T = 6 * K
    prev = None
    for t in range(T):
        cur = sw.mask(t)
        assert cur.sum() == n_byz  # exactly n_byz True every round
        if prev is not None and not (cur == prev).all():
            assert t % K == 0, f"switched mid-epoch at t={t}, K={K}"
        prev = cur


@settings(max_examples=10, deadline=None)
@given(st.integers(4, 20), st.integers(1, 4), st.integers(0, 10))
def test_prop_mask_schedule_equals_within_round(m, n_max_log, seed):
    n_max = 2 ** n_max_log
    T = 20
    for make in (lambda: Static(m, m // 3, seed=seed),
                 lambda: Periodic(m, m // 3 + 1, K=4, seed=seed),
                 lambda: Bernoulli(m, p=0.2, D=3, delta_max=0.5, seed=seed),
                 lambda: MomentumTailored(m, alpha=0.21, seed=seed)):
        sched = make().mask_schedule(T, n_max)
        ref = make()
        assert sched.shape == (T, n_max, m)
        for t in range(T):
            for k in range(n_max):
                np.testing.assert_array_equal(
                    sched[t, k], ref.within_round(t, k),
                    err_msg=f"{type(ref).__name__} t={t} k={k}")


def test_mask_schedule_empty_T():
    for sw in (Static(6, 2), Periodic(6, 2, K=3),
               Bernoulli(6, p=0.1, D=3, delta_max=0.5), MomentumTailored(6, 0.2)):
        assert sw.mask_schedule(0, 4).shape == (0, 4, 6)


def test_mask_schedule_subclass_overriding_mask_bypasses_parent_fast_path():
    """A subclass overriding mask() must not inherit the parent's vectorized
    schedule (which knows nothing of the new masks)."""

    class Drifting(Static):
        def mask(self, t):
            return np.roll(self._mask, t)

    sw = Drifting(7, 3, seed=2)
    sched = sw.mask_schedule(12, 2)
    ref = Drifting(7, 3, seed=2)
    for t in range(12):
        for k in range(2):
            np.testing.assert_array_equal(sched[t, k], ref.within_round(t, k))


def test_mask_schedule_generic_fallback_within_round():
    """A custom within-round strategy goes through the generic loop."""

    class Alternating(Static):
        def within_round(self, t, k):
            return self._mask if k % 2 == 0 else ~self._mask

    sw = Alternating(6, 2, seed=0)
    sched = sw.mask_schedule(5, 4)
    np.testing.assert_array_equal(sched[:, 0], np.broadcast_to(sw._mask, (5, 6)))
    np.testing.assert_array_equal(sched[:, 1], np.broadcast_to(~sw._mask, (5, 6)))


# ------------------------------------------------- attack invariances


def _mixed_stack(m=8, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(m, 3, 4)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(m, 5)).astype(np.float32)),
    }


@pytest.mark.parametrize("name", sorted(atk.ATTACKS))
def test_attack_all_false_mask_is_noop(name):
    s = _mixed_stack()
    out = atk.get_attack(name)(s, jnp.zeros(8, bool), key=jax.random.PRNGKey(0))
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", sorted(atk.ATTACKS))
@pytest.mark.parametrize("mask", [
    [True] + [False] * 7,
    [True, False] * 4,
    [False] * 4 + [True] * 4,
])
def test_attack_honest_rows_bit_identical(name, mask):
    s = _mixed_stack(seed=3)
    mask = jnp.asarray(mask)
    out = atk.get_attack(name)(s, mask, key=jax.random.PRNGKey(1))
    honest = np.flatnonzero(~np.asarray(mask))
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a)[honest],
                                      np.asarray(b)[honest],
                                      err_msg=f"{name} perturbed honest rows")
