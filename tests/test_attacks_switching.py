"""Attacks (Appendix J) and identity-switching strategies (Section 6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks as atk
from repro.core.switching import Bernoulli, MomentumTailored, Periodic, Static, get_switcher


def _stack(m=8, d=5, seed=0):
    return {"g": jnp.asarray(np.random.default_rng(seed).normal(size=(m, d)).astype(np.float32))}


def test_sign_flip():
    s = _stack()
    mask = jnp.array([True] + [False] * 7)
    out = atk.sign_flip(s, mask)
    np.testing.assert_allclose(np.asarray(out["g"][0]), -np.asarray(s["g"][0]))
    np.testing.assert_allclose(np.asarray(out["g"][1:]), np.asarray(s["g"][1:]))


def test_ipm_sends_scaled_negative_mean():
    s = _stack()
    mask = jnp.array([True, True] + [False] * 6)
    out = atk.ipm(s, mask, eps=0.1)
    hm = np.asarray(s["g"][2:]).mean(0)
    np.testing.assert_allclose(np.asarray(out["g"][0]), -0.1 * hm, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out["g"][1]), -0.1 * hm, rtol=1e-5)


def test_alie_within_noise_envelope():
    s = _stack(m=20, d=3, seed=2)
    mask = jnp.asarray([True] * 4 + [False] * 16)
    out = atk.alie(s, mask, z=1.0)
    h = np.asarray(s["g"][4:])
    mu, sd = h.mean(0), h.std(0)
    np.testing.assert_allclose(np.asarray(out["g"][0]), mu - 1.0 * sd, rtol=1e-4, atol=1e-5)


def test_attack_registry_none_identity():
    s = _stack()
    out = atk.get_attack("none")(s, jnp.ones(8, bool))
    np.testing.assert_allclose(np.asarray(out["g"]), np.asarray(s["g"]))


# ------------------------------------------------------------- switching


def test_static_mask_fixed():
    sw = Static(10, 4, seed=1)
    m0 = sw.mask(0)
    assert m0.sum() == 4
    for t in range(50):
        assert (sw.mask(t) == m0).all()


def test_periodic_switches_every_K():
    sw = Periodic(17, 8, K=10, seed=0)
    assert all(sw.mask(t).sum() == 8 for t in range(40))
    m0, m10 = sw.mask(0), sw.mask(10)
    assert (sw.mask(9) == m0).all()
    assert not (m10 == m0).all()  # overwhelmingly likely with 17 choose 8
    sw2 = Periodic(17, 8, K=10, seed=0)
    assert (sw2.mask(25) == sw.mask(25)).all()  # deterministic


def test_bernoulli_caps_fraction_and_duration():
    sw = Bernoulli(25, p=0.05, D=10, delta_max=0.48, seed=0)
    cap = int(0.48 * 25)
    counts = [sw.mask(t).sum() for t in range(500)]
    assert max(counts) <= cap
    assert max(counts) > 0  # attacks do happen
    # durations: once byzantine, stays for D rounds
    m = np.stack([sw.mask(t) for t in range(500)])
    for i in range(25):
        runs = np.diff(np.flatnonzero(np.diff(m[:, i].astype(int)) != 0))
        if len(runs) > 2:
            byz_runs = runs[::2] if m[np.flatnonzero(np.diff(m[:, i].astype(int)))[0] + 1, i] else runs[1::2]
            assert all(r == 10 for r in byz_runs[:-1])
            break


def test_momentum_tailored_single_worker_rotation():
    sw = MomentumTailored(3, alpha=0.1)
    period, third = 10, 3
    masks = [sw.mask(t) for t in range(30)]
    assert all(mk.sum() == 1 for mk in masks)
    # rotates among the three workers, O(sqrt T) switches
    seen = {tuple(mk) for mk in masks}
    assert len(seen) == 3
    assert sw.switch_rounds(300) <= 3 * 0.1 * 300 + 3


def test_switch_rounds_counter():
    sw = Periodic(8, 3, K=25, seed=0)
    assert sw.switch_rounds(100) <= 4


def test_get_switcher_registry():
    for name, kw in [("static", {"n_byz": 2}), ("periodic", {"n_byz": 2, "K": 5}),
                     ("bernoulli", {"p": 0.1, "D": 5, "delta_max": 0.5}),
                     ("momentum_tailored", {"alpha": 0.1})]:
        sw = get_switcher(name, 8, **kw)
        assert sw.mask(0).shape == (8,)
