"""End-to-end Mode A training behaviour: the paper's core claims at test scale.

Problem: 2D quadratic f(x) = 0.5 xᵀAx (Appendix E's setup) — exact optimum 0.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import (
    DynaBROConfig, make_dynabro_step, run_dynabro, run_momentum,
)
from repro.core.switching import get_switcher
from repro.optim.optimizers import adagrad_norm, sgd

A = jnp.array([[2.0, 1.0], [1.0, 2.0]])
SIGMA = 0.5


def grad_fn(params, unit_key):
    return {"x": A @ params["x"] + SIGMA * jax.random.normal(unit_key, (2,))}


def sampler(m, seed=0):
    def sample(t, n):
        keys = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(seed), t), m * n)
        return keys.reshape(m, n, *keys.shape[1:])
    return sample


def f_val(p):
    return float(0.5 * p["x"] @ A @ p["x"])


P0 = {"x": jnp.array([3.0, -2.0])}


def _cfg(agg="cwmed", attack="sign_flip", m=9, T=300, option=1, delta=0.25, **akw):
    return DynaBROConfig(
        mlmc=MLMCConfig(T=T, m=m, V=4 * SIGMA + 1, option=option, kappa=1.0),
        aggregator=agg, delta=delta, attack=attack, attack_kwargs=akw or None)


def test_dynabro_converges_under_static_signflip():
    m, T = 9, 300
    sw = get_switcher("static", m, n_byz=4)
    p, logs, _ = run_dynabro(grad_fn, P0, sgd(2e-2), _cfg(m=m, T=T), sw,
                             sampler(m), T)
    assert f_val(p) < 0.1 * f_val(P0)
    assert {l.level for l in logs} >= {1, 2}  # geometric levels exercised


def test_dynabro_converges_under_periodic_switching():
    """Fig. 1's qualitative claim: stability across switching rates K."""
    m, T = 9, 300
    finals = []
    for K in (5, 50):
        sw = get_switcher("periodic", m, n_byz=4, K=K)
        p, _, _ = run_dynabro(grad_fn, P0, sgd(2e-2),
                              _cfg(agg="cwtm", m=m, T=T, delta=4 / 9 + 0.01),
                              sw, sampler(m), T)
        finals.append(f_val(p))
    assert max(finals) < 0.15 * f_val(P0)
    # stability: fast switching is not catastrophically worse
    assert finals[0] < 10 * max(finals[1], 1e-3)


def test_mean_aggregation_fails_where_cwmed_survives():
    m, T = 9, 200
    sw = get_switcher("static", m, n_byz=4)
    cfg_mean = _cfg(agg="mean", attack="sign_flip", m=m, T=T)
    cfg_med = _cfg(agg="cwmed", attack="sign_flip", m=m, T=T)
    p_mean, _, _ = run_dynabro(grad_fn, P0, sgd(2e-2), cfg_mean, sw, sampler(m), T)
    p_med, _, _ = run_dynabro(grad_fn, P0, sgd(2e-2), cfg_med, sw, sampler(m), T)
    assert f_val(p_med) < f_val(p_mean)


def test_momentum_breaks_under_tailored_dynamic_attack():
    """Appendix E: the dynamic attack defeats worker-momentum while DynaBRO
    (MLMC + fail-safe) keeps converging under the same switch budget."""
    m, T = 3, 600
    sw = get_switcher("momentum_tailored", m, alpha=0.05)
    cfg = _cfg(agg="cwmed", attack="shift", m=m, T=T, v=3.0)
    p_mom, _ = run_momentum(grad_fn, P0, cfg, sw, sampler(m), T,
                            lr=2e-2, beta=0.95)
    p_dyn, _, _ = run_dynabro(grad_fn, P0, sgd(2e-2), cfg, sw, sampler(m), T)
    assert f_val(p_dyn) < f_val(p_mom), (f_val(p_dyn), f_val(p_mom))


def test_adagrad_norm_needs_no_smoothness_knowledge():
    """Section 5: Option 2 (MFM) + AdaGrad-Norm converges without L or δ."""
    m, T = 9, 300
    sw = get_switcher("static", m, n_byz=3)
    cfg = _cfg(agg="mfm", attack="sign_flip", m=m, T=T, option=2)
    p, logs, _ = run_dynabro(grad_fn, P0, adagrad_norm(1.0), cfg, sw,
                             sampler(m), T)
    assert f_val(p) < 0.2 * f_val(P0)


def test_failsafe_fires_on_within_round_switches():
    """Dynamic rounds (Section 4): identities flipping *within* a round can
    corrupt the high MLMC levels; the fail-safe must bound the damage."""
    m, T = 8, 60

    class WithinRound:
        m = 8

        def mask(self, t):
            return np.zeros(8, bool)

        def within_round(self, t, k):
            mk = np.zeros(8, bool)
            if k % 2 == 1:  # half the computations are Byzantine for 4 workers
                mk[:4] = True
            return mk

    cfg = _cfg(agg="cwmed", attack="shift", m=m, T=T, v=200.0)
    p, logs, _ = run_dynabro(grad_fn, P0, sgd(1e-2), cfg, WithinRound(),
                             sampler(m), T, seed=5)
    trips = [l for l in logs if l.level >= 1 and not l.failsafe_ok]
    assert trips, "fail-safe never fired under within-round corruption"
    assert np.isfinite(f_val(p)) and f_val(p) < 100.0


def test_step_is_jittable_and_deterministic():
    m = 5
    cfg = _cfg(m=m, T=64)
    step = make_dynabro_step(grad_fn, cfg, sgd(1e-2))
    batches = sampler(m)(0, 2)
    masks = jnp.zeros((2, m), bool)
    key = jax.random.PRNGKey(0)
    p1, _, _ = step(P0, (), batches, masks, key, 1)
    p2, _, _ = step(P0, (), batches, masks, key, 1)
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]))
