"""Substrate: optimizers, data pipeline, checkpointing, config registry."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import ARCH_IDS, SHAPES, get_config, reduced
from repro.data import SyntheticLMData, gaussian_mixture_dataset
from repro.optim.optimizers import adagrad_norm, adam, apply_updates, momentum, sgd


def test_sgd_step():
    opt = sgd(0.5)
    p = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([1.0])}
    u, _ = opt.update(g, opt.init(p))
    p2 = apply_updates(p, u)
    np.testing.assert_allclose(np.asarray(p2["w"]), [1.5])


def test_momentum_accumulates():
    opt = momentum(1.0, beta=0.5)
    p = {"w": jnp.zeros(1)}
    s = opt.init(p)
    g = {"w": jnp.ones(1)}
    u1, s = opt.update(g, s)
    u2, s = opt.update(g, s)
    assert float(u2["w"][0]) > float(u1["w"][0])  # momentum builds up


def test_adam_bias_correction_first_step():
    opt = adam(1e-1)
    p = {"w": jnp.zeros(3)}
    s = opt.init(p)
    u, s = opt.update({"w": jnp.full(3, 0.5)}, s)
    np.testing.assert_allclose(np.asarray(u["w"]), 0.1, rtol=1e-3)


def test_adagrad_norm_monotone_lr():
    """Eq. (7): effective lr is non-increasing; scale-free in eta0."""
    opt = adagrad_norm(1.0)
    p = {"w": jnp.zeros(2)}
    acc = opt.init(p)
    g = {"w": jnp.ones(2)}
    norms = []
    for _ in range(5):
        u, acc = opt.update(g, acc)
        norms.append(float(jnp.linalg.norm(u["w"])))
    assert all(a >= b for a, b in zip(norms, norms[1:]))
    np.testing.assert_allclose(norms[0], 1.0 / np.sqrt(2) * np.sqrt(2), rtol=1e-4)


def test_synthetic_lm_deterministic_and_sharded():
    ds = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    b1, b2 = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(ds.batch(6)["tokens"]), np.asarray(b1["tokens"]))
    w0 = ds.worker_batch(5, 0, 4)
    w1 = ds.worker_batch(5, 1, 4)
    assert not np.array_equal(np.asarray(w0["tokens"]), np.asarray(w1["tokens"]))
    assert int(b1["tokens"].max()) < 100
    np.testing.assert_array_equal(np.asarray(b1["labels"][:, :-1]),
                                  np.asarray(b1["tokens"][:, 1:]))


def test_gaussian_mixture_learnable():
    X, y = gaussian_mixture_dataset(4, 8, 2000, seed=0, noise=0.3)
    # nearest-mean classifier should beat chance by a lot
    means = np.stack([X[y == c].mean(0) for c in range(4)])
    pred = ((X[:, None] - means[None]) ** 2).sum(-1).argmin(1)
    assert (pred == y).mean() > 0.9


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "d": [jnp.zeros((2,)), jnp.full((1,), 7.0)]}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt")
        save_checkpoint(path, tree, step=11)
        back = load_checkpoint(path, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))


def test_config_registry_complete():
    assert len(ARCH_IDS) == 10
    fams = {get_config(a).family for a in ARCH_IDS}
    assert fams == {"dense", "moe", "hybrid", "ssm", "audio", "vlm"}
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["long_500k"].seq_len == 524_288


def test_reduced_configs_small():
    for a in ARCH_IDS:
        c = reduced(get_config(a))
        assert c.d_model <= 512
        assert c.n_layers <= max(8, c.group_size)
        if c.is_moe:
            assert c.n_experts <= 4
