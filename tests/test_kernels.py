"""Pallas kernel sweeps (interpret mode on CPU) vs the ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import cwmed_op, cwtm_op, pairwise_sqdist_op
from repro.kernels.ref import cwmed_ref, cwtm_ref, pairwise_sqdist_ref


@pytest.mark.parametrize("m", [3, 8, 16, 17, 25, 32])
@pytest.mark.parametrize("d", [64, 1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cwmed_sweep(m, d, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(m * d), (m, d)) * 3).astype(dtype)
    got = np.asarray(cwmed_op(x))
    want = np.asarray(cwmed_ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,trim", [(8, 0), (8, 2), (16, 4), (17, 5), (32, 8)])
@pytest.mark.parametrize("d", [50, 2048])
def test_cwtm_sweep(m, trim, d):
    x = jax.random.normal(jax.random.PRNGKey(m + trim + d), (m, d))
    got = np.asarray(cwtm_op(x, trim))
    want = np.asarray(cwtm_ref(x, trim))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m", [2, 16, 25])
@pytest.mark.parametrize("d", [128, 3000, 8192])
def test_pairwise_sweep(m, d):
    x = jax.random.normal(jax.random.PRNGKey(m * d + 1), (m, d))
    got = np.asarray(pairwise_sqdist_op(x, tile_d=1024))
    want = np.asarray(pairwise_sqdist_ref(x))
    scale = want.max() + 1e-9
    np.testing.assert_allclose(got / scale, want / scale, atol=2e-6)


def test_cwmed_robust_to_inf_magnitude_outlier():
    x = jax.random.normal(jax.random.PRNGKey(0), (9, 256))
    x = x.at[0].set(1e30)
    got = np.asarray(cwmed_op(x))
    assert np.abs(got).max() < 10


def test_cwmed_tile_not_dividing_d():
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 777))
    np.testing.assert_allclose(np.asarray(cwmed_op(x, tile_d=256)),
                               np.asarray(cwmed_ref(x)), rtol=1e-5, atol=1e-5)
