"""SyntheticLMData contracts: explicit-batch validation (the PR-7
``batch or global_batch`` bugfix) and the nested-prefix MLMC unit grids the
model-zoo driver samples from."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import SyntheticLMData


def _ds():
    return SyntheticLMData(vocab_size=64, seq_len=8, global_batch=4, seed=1)


def test_batch_none_promotes_to_global():
    ds = _ds()
    assert ds.batch(0)["tokens"].shape == (4, 8)
    assert ds.batch(0, None)["tokens"].shape == (4, 8)
    assert ds.batch(0, 2)["tokens"].shape == (2, 8)


def test_batch_zero_and_negative_raise():
    # `batch or self.global_batch` silently promoted an explicit 0 to the
    # global batch; only None may do that
    ds = _ds()
    with pytest.raises(ValueError, match="positive"):
        ds.batch(0, 0)
    with pytest.raises(ValueError, match="positive"):
        ds.batch(0, -2)


def test_mlmc_batches_nested_prefix():
    ds = _ds()
    m, ub = 3, 2
    b4 = ds.mlmc_batches(5, m, 4, ub)
    b2 = ds.mlmc_batches(5, m, 2, ub)
    assert b4["tokens"].shape == (m, 4, ub, 8)
    # level j-1 is the prefix of level j (the MLMC nesting, DESIGN.md §3)
    np.testing.assert_array_equal(np.asarray(b4["tokens"][:, :2]),
                                  np.asarray(b2["tokens"]))
    np.testing.assert_array_equal(
        np.asarray(b4["labels"]),
        np.roll(np.asarray(b4["tokens"]), -1, axis=3))
    with pytest.raises(ValueError, match="positive"):
        ds.mlmc_batches(0, m, 2, 0)


def test_mlmc_batches_traceable_in_step():
    # the scan driver vectorizes the batch schedule by vmapping the sampler
    # over t — the vmapped draw must equal the per-t draws
    ds = _ds()
    stacked = jax.vmap(lambda t: ds.mlmc_batches(t, 3, 2, 2))(jnp.arange(3))
    for t in range(3):
        np.testing.assert_array_equal(
            np.asarray(stacked["tokens"][t]),
            np.asarray(ds.mlmc_batches(t, 3, 2, 2)["tokens"]))


def test_mlmc_sampler_closure_matches_direct():
    ds = _ds()
    s = ds.mlmc_sampler(3, 2)
    np.testing.assert_array_equal(
        np.asarray(s(7, 2)["tokens"]),
        np.asarray(ds.mlmc_batches(7, 3, 2, 2)["tokens"]))
