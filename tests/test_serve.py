"""``repro.serve`` — the continuously-running aggregation service.

The contracts that matter (DESIGN.md §10): a fully-delivered worker stream
is *bitwise*-identical to the offline compiled driver (the serve loop drives
the same compiled segment on length-1 slices); a timed-out worker is masked
as dynamically Byzantine for exactly that round (server == an offline replay
that ORs the same bits); a killed server resumes from its last periodic
checkpoint bitwise; the bounded ring and the lookahead window apply
backpressure instead of dropping.
"""
import json
import threading
import time
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import build_session
from repro.checkpoint import latest_checkpoint
from repro.core.mlmc import MLMCConfig
from repro.core.robust_train import DynaBROConfig
from repro.core.scenarios import make_quadratic_task
from repro.core.switching import get_switcher
from repro.optim.optimizers import adagrad_norm
from repro.serve import (
    AggregationServer, HealthEndpoint, MetricsLog, RingBuffer, ServeConfig,
    ServeMetrics, SimulatedWorkers, worker_payloads,
)

TASK = make_quadratic_task()
M, T, SEED = 16, 12, 11


def _session(m=M, T_=T, seed=SEED):
    cfg = DynaBROConfig(mlmc=MLMCConfig(T=T_, m=m, V=3.0, kappa=1.0, j_cap=2),
                        aggregator="cwmed", delta=0.4, attack="sign_flip")
    switcher = get_switcher("periodic", m, n_byz=m // 4, K=4, seed=seed)
    return build_session(cfg, TASK, switcher=switcher,
                         opt=adagrad_norm(2e-2), seed=seed)


def _tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------- ring


def test_ring_fifo_and_high_water():
    ring = RingBuffer(4)
    for i in range(3):
        assert ring.put(i)
    assert [ring.get() for _ in range(3)] == [0, 1, 2]
    st = ring.stats()
    assert st["ring_pushed"] == 3 and st["ring_high_water"] == 3
    assert st["ring_depth"] == 0 and st["ring_rejected"] == 0


def test_ring_overflow_backpressure():
    """A full ring blocks the producer; past the timeout the put is REJECTED
    (False + counted), never silently dropped or overwritten."""
    ring = RingBuffer(2)
    assert ring.put("a") and ring.put("b")
    t0 = time.monotonic()
    assert ring.put("c", timeout=0.1) is False
    assert time.monotonic() - t0 >= 0.09
    assert ring.stats()["ring_rejected"] == 1
    # draining one slot unblocks a waiting producer
    unblocked = []
    th = threading.Thread(
        target=lambda: unblocked.append(ring.put("c", timeout=5.0)))
    th.start()
    assert ring.get() == "a"
    th.join(5.0)
    assert unblocked == [True]
    assert ring.get() == "b" and ring.get() == "c"


def test_ring_close_wakes_waiters_and_drains():
    ring = RingBuffer(1)
    assert ring.put("x")
    results = []
    producer = threading.Thread(
        target=lambda: results.append(ring.put("y", timeout=10.0)))
    producer.start()
    time.sleep(0.05)
    ring.close()
    producer.join(5.0)
    assert results == [False]          # blocked put rejected on close
    assert ring.get() == "x"           # queued items stay drainable
    assert ring.get(timeout=0.01) is None
    assert ring.put("z") is False
    with pytest.raises(ValueError, match="capacity"):
        RingBuffer(0)


# ----------------------------------------------------- metrics / health


def test_metrics_counters_window_and_log(tmp_path):
    m = ServeMetrics(window_s=60.0)
    m.inc("updates_accepted", 3)
    m.mark_updates(3)
    m.observe_staleness(0.2)
    m.observe_staleness(0.4)
    snap = m.snapshot()
    assert snap["updates_accepted"] == 3
    assert snap["updates_per_sec"] > 0
    assert snap["staleness_mean_s"] == pytest.approx(0.3)
    assert snap["staleness_max_s"] == pytest.approx(0.4)

    path = tmp_path / "metrics.jsonl"
    log = MetricsLog(str(path))
    log.write({"event": "round", "round": 0})
    log.close()
    [rec] = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert rec["event"] == "round" and "ts" in rec
    MetricsLog(None).write({"noop": True})  # None path is a no-op


def test_health_endpoint_routes():
    ep = HealthEndpoint(lambda: {"status": "live", "round": 4,
                                 "rounds_total": 8, "extra": 1.5})
    ep.start()
    try:
        with urllib.request.urlopen(ep.url + "/health", timeout=5) as r:
            health = json.load(r)
        assert health == {"status": "live", "round": 4, "rounds_total": 8}
        with urllib.request.urlopen(ep.url + "/metrics", timeout=5) as r:
            assert json.load(r)["extra"] == 1.5
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(ep.url + "/nope", timeout=5)
        assert ei.value.code == 404
    finally:
        ep.stop()


# ------------------------------------------------------------- server


def test_submit_validation_and_lookahead_backpressure():
    """Far-future rounds block in admission (bounded memory) and time out as
    backpressure; invalid ids are rejected outright. No loop is running, so
    the current round stays 0 throughout."""
    sess = _session()
    server = AggregationServer(sess, T, ServeConfig(lookahead_rounds=2))
    payload = worker_payloads(sess, T)[0][0]
    assert server.submit(-1, 0, payload) is False
    assert server.submit(0, T, payload) is False
    assert server.submit(0, 0, payload, timeout=1.0) is True
    t0 = time.monotonic()
    assert server.submit(0, 2, payload, timeout=0.15) is False
    assert time.monotonic() - t0 >= 0.1
    snap = server.snapshot()
    assert snap["updates_invalid"] == 2
    assert snap["updates_backpressured"] == 1
    assert snap["status"] == "live" and snap["round"] == 0
    server.close()
    assert server.submit(0, 0, payload) is False  # post-shutdown reject


def test_stream_matches_offline_driver_bitwise(tmp_path):
    """The acceptance contract: a 16-worker simulated client stream, with
    submission jitter exercising cross-round reordering, yields final params
    bitwise-identical to the offline compiled scan driver, plus matching
    round logs, health progress and a structured metrics trail."""
    params_ref, logs_ref, _ = _session().run(T)

    sess = _session()
    log_path = tmp_path / "serve.jsonl"
    server = AggregationServer(sess, T, ServeConfig(
        capacity=64, lookahead_rounds=4, health_port=0,
        metrics_log=str(log_path)))
    server.start()
    workers = SimulatedWorkers(server, worker_payloads(sess, T),
                               jitter_s=0.002).start()
    assert workers.join(timeout=120.0) and not workers.failures
    assert server.join(timeout=120.0), server.snapshot()

    with urllib.request.urlopen(server.health.url + "/health",
                                timeout=5) as r:
        health = json.load(r)
    server.close()
    assert server.error is None
    assert health["status"] == "completed"
    assert health["round"] == T and health["rounds_completed"] == T
    assert health["updates_accepted"] == M * T

    _tree_equal(server.params, params_ref)
    assert server.logs == logs_ref
    events = [json.loads(ln) for ln in log_path.read_text().splitlines()]
    rounds = [e for e in events if e["event"] == "round"]
    assert [e["round"] for e in rounds] == list(range(T))
    assert all(e["workers"] == M and e["stragglers"] == 0 for e in rounds)


def test_straggler_timeout_masks_as_byzantine():
    """Workers that miss the round deadline are ORed into that round's
    Byzantine mask with an inert zero-filled batch slot — the server output
    is bitwise-identical to an offline step replay applying the exact same
    masking, and the metrics count each masked straggler."""
    drop = {(2, 3), (9, 3), (5, 7)}
    sess = _session()
    sched = sess.schedule(T)

    # offline reference replay: same zero-fill + mask-OR, no server involved
    carry = sess.init_carry()
    for t in range(T):
        inp = sess.round_inputs(sched, t)
        dropped = [w for w, r in drop if r == t]
        if dropped:
            masks = np.array(inp.masks)
            masks[..., dropped] = True
            inp.masks = masks
            keep = jnp.asarray([w not in dropped for w in range(M)])
            inp.batches = jax.tree.map(
                lambda l: jnp.where(
                    keep.reshape((-1,) + (1,) * (l.ndim - 1)), l,
                    jnp.zeros_like(l)), inp.batches)
        carry, _ = sess.step(carry, inp)

    server = AggregationServer(_session(), T, ServeConfig(
        round_timeout_s=0.25, min_workers=1))
    server.start()
    workers = SimulatedWorkers(server, worker_payloads(sess, T),
                               drop=drop).start()
    assert workers.join(timeout=120.0) and not workers.failures
    assert server.join(timeout=120.0), server.snapshot()
    snap = server.snapshot()
    server.close()
    assert server.error is None
    assert snap["stragglers_masked"] == len(drop)
    assert snap["updates_accepted"] == M * T - len(drop)
    _tree_equal(server.params, carry[0])
    # the straggler rounds count the ORed bits as Byzantine in the logs
    for t, dropped in ((3, [2, 9]), (7, [5])):
        expected = np.logical_or(sched.masks[t][0],
                                 np.isin(np.arange(M), dropped))
        assert server.logs[t].n_byz == int(expected.sum())


def test_kill_resume_is_bitwise(tmp_path):
    """Mid-stream kill/resume: periodic checkpoints every 4 rounds, a hard
    stop (no final checkpoint) after round 6, resume from the newest
    checkpoint (round 4), replay from there — final params bitwise-match an
    uninterrupted offline run, and a graceful drain then leaves a final
    checkpoint at the exact boundary T."""
    params_ref, _, _ = _session().run(T)
    ckpt_dir = str(tmp_path / "ckpts")
    (tmp_path / "ckpts").mkdir()
    cfg = ServeConfig(checkpoint_every=4, checkpoint_dir=ckpt_dir)

    sess = _session()
    payloads = worker_payloads(sess, T)
    server = AggregationServer(sess, T, cfg)
    server.start()
    SimulatedWorkers(server, payloads[:6]).start().join(timeout=120.0)
    deadline = time.monotonic() + 120.0
    while server.round < 6 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert server.round == 6, server.snapshot()
    server.stop(drain=False)  # kill: rounds 4-5 die with the process
    server.close()
    found = latest_checkpoint(ckpt_dir, prefix="carry_")
    assert found is not None and found[1] == 4

    sess2 = _session()
    resumed = AggregationServer.resume(sess2, T, cfg)
    assert resumed.start_round == 4
    resumed.start()
    workers = SimulatedWorkers(resumed, worker_payloads(sess2, T, start=4),
                               start_round=4).start()
    assert workers.join(timeout=120.0) and not workers.failures
    assert resumed.join(timeout=120.0), resumed.snapshot()
    resumed.stop(drain=True)
    resumed.close()
    assert resumed.error is None

    _tree_equal(resumed.params, params_ref)
    assert latest_checkpoint(ckpt_dir, prefix="carry_")[1] == T
    # and a third resume starts at T with nothing left to do
    assert AggregationServer.resume(_session(), T, cfg).start_round == T
